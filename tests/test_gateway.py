"""The serving gateway (repro.serve): HTTP front door, plan cache, admission.

Covers the workflow-as-a-service tentpole end to end:

* **submission decoding** — DAG-JSON and ``.swirl`` bodies compile to
  plans; every malformed input is a typed :class:`SubmissionError` that
  the gateway maps to a ``400`` JSON body (with 1-based line/column for
  ``.swirl`` syntax errors) — never a traceback;
* **content addressing** — resubmission hits the source-digest level,
  different sources that compile to the same plan converge on one cached
  artifact via :meth:`Plan.fingerprint`, the LRU evicts aliases with
  their entry;
* **execution over HTTP** — run / run_many against a fingerprint on the
  shared threaded Executable, with concurrent client batches isolated;
* **admission control** — per-tenant quotas, strict FIFO queues,
  ``429`` + ``Retry-After`` under overload, ``401``/``404`` mapping, and
  graceful drain (in-flight work finishes; new work gets ``503``).
"""

from __future__ import annotations

import json
import threading
import time

import pytest

from repro import swirl
from repro.core.parser import dumps
from repro.serve import (
    AdmissionController,
    AdmissionRejected,
    Gateway,
    GatewayClient,
    GatewayError,
    PlanCache,
    SubmissionError,
    TenantConfig,
    UnknownTenantError,
    WorkflowService,
)
from repro.serve.cache import CacheEntry
from repro.serve.submission import compile_submission

EDGES = {"prep": ["work"], "work": ["sink"], "sink": []}
MAPPING = {"prep": ["l1"], "work": ["l2"], "sink": ["l1"]}
DAG_BODY = {"dag": {"edges": EDGES, "mapping": MAPPING}}


def step_registry(sleep_s: float = 0.0):
    def prep(inp):
        if sleep_s:
            time.sleep(sleep_s)
        return {"d^prep": [1]}

    return {
        "prep": prep,
        "work": lambda inp: {"d^work": inp["d^prep"] + [2]},
        "sink": lambda inp: {},
    }


@pytest.fixture
def service():
    return WorkflowService(step_registry())


@pytest.fixture
def gateway(service):
    with Gateway(service) as gw:
        yield gw


@pytest.fixture
def client(gateway):
    with GatewayClient(gateway.url) as c:
        yield c


# ---------------------------------------------------------------------------
# Submission decoding
# ---------------------------------------------------------------------------


class TestSubmission:
    def test_dag_body_compiles(self):
        plan, meta = compile_submission(dict(DAG_BODY, rules=["R1R2"]))
        assert set(plan.steps()) == {"prep", "work", "sink"}
        assert meta == {"format": "dag", "rules": ["R1R2"]}

    def test_swirl_body_compiles(self):
        text = dumps(compile_submission(dict(DAG_BODY, rules=[]))[0].system)
        plan, meta = compile_submission({"swirl": text})
        assert set(plan.steps()) == {"prep", "work", "sink"}
        assert meta["format"] == "swirl"

    def test_raw_string_is_swirl(self):
        text = dumps(compile_submission(dict(DAG_BODY, rules=[]))[0].system)
        plan, _ = compile_submission(text)
        assert set(plan.steps()) == {"prep", "work", "sink"}

    @pytest.mark.parametrize(
        "body, kind",
        [
            (42, "schema"),
            ({"dag": DAG_BODY["dag"], "swirl": "x"}, "schema"),
            ({"frobnicate": 1}, "schema"),
            ({"swirl": ""}, "schema"),
            ({"swirl": "<l,{},bogus(s)>"}, "swirl-syntax"),
            ({"dag": {"edges": {}}}, "dag"),
            ({"dag": {"edges": EDGES}}, "dag"),
            (
                {"dag": {"edges": EDGES, "mapping": {"prep": ["l1"]}}},
                "dag",
            ),
            (
                {
                    "dag": {
                        "edges": {"a.b": ["c"], "c": []},
                        "mapping": {"a.b": ["l"], "c": ["l"]},
                    }
                },
                "dag",
            ),
            (
                {
                    "dag": dict(
                        DAG_BODY["dag"], initial_data={"l1": ["nope"]}
                    )
                },
                "dag",
            ),
            (dict(DAG_BODY, rules=["R99"]), "rules"),
            (dict(DAG_BODY, rules="R1R2"), "rules"),
        ],
    )
    def test_malformed_bodies_are_typed_errors(self, body, kind):
        with pytest.raises(SubmissionError) as exc:
            compile_submission(body)
        assert exc.value.kind == kind
        assert exc.value.to_json()["type"] == "SubmissionError"

    def test_swirl_syntax_error_carries_position(self):
        with pytest.raises(SubmissionError) as exc:
            compile_submission({"swirl": "<l, {d1},\n  bogus(s)>"})
        e = exc.value
        assert e.kind == "swirl-syntax"
        assert e.line == 2 and e.column == 3
        body = e.to_json()
        assert body["line"] == 2 and body["column"] == 3

    def test_network_enables_schedule_stage(self):
        # An operator-configured cost model inserts Plan.schedule between
        # optimize and lower: the author's static mapping is replaced by
        # auto-placement, and the served instance still runs correctly.
        from repro.sched import NetworkModel

        svc = WorkflowService(step_registry(), network=NetworkModel())
        receipt = svc.submit(DAG_BODY)
        entry = svc.cache.peek(receipt["fingerprint"])
        assert entry is not None
        assert any(
            label.startswith("schedule") for label, _ in entry.plan.timings
        ), [label for label, _ in entry.plan.timings]
        result = svc.run(receipt["fingerprint"])
        produced = {
            d: v
            for loc in result["data"].values()
            for d, v in loc.items()
        }
        assert produced["d^work"] == [1, 2]
        # Placement-equivalent resubmission of the same source is a hit.
        assert svc.submit(DAG_BODY)["cached"] is True


# ---------------------------------------------------------------------------
# The content-addressed plan cache
# ---------------------------------------------------------------------------


def _entry(tag: str) -> CacheEntry:
    plan = swirl.trace(EDGES, mapping=MAPPING).optimize()
    exe = plan.lower("threaded").compile(step_registry())
    return CacheEntry(
        fingerprint=tag * 64, plan=plan, executable=exe, compile_seconds=0.5
    )


class TestPlanCache:
    def test_hit_miss_stats(self):
        cache = PlanCache(4)
        e = cache.put(_entry("a"), source_digest="src1")
        assert cache.get("a" * 64) is e
        assert cache.get("b" * 64) is None
        assert cache.lookup_source("src1") is e
        s = cache.stats()
        assert s["hits"] == 2 and s["misses"] == 1
        assert s["hit_rate"] == pytest.approx(2 / 3, abs=1e-3)
        assert s["compile_seconds_saved"] == pytest.approx(1.0)

    def test_same_fingerprint_aliases_not_duplicates(self):
        cache = PlanCache(4)
        first = cache.put(_entry("a"), source_digest="src1")
        second = cache.put(_entry("a"), source_digest="src2")
        assert second is first  # the existing artifact wins
        assert len(cache) == 1
        assert cache.lookup_source("src2") is first

    def test_lru_eviction_takes_aliases(self):
        cache = PlanCache(2)
        cache.put(_entry("a"), source_digest="src-a")
        cache.put(_entry("b"))
        cache.get("a" * 64)  # refresh a → b is now LRU... then evict a? no:
        cache.put(_entry("c"))  # evicts b (least recently used)
        assert cache.peek("b" * 64) is None
        assert cache.peek("a" * 64) is not None
        cache.put(_entry("d"))  # evicts a and its source alias
        assert cache.peek("a" * 64) is None
        assert cache.lookup_source("src-a") is None
        assert cache.stats()["evictions"] == 2


# ---------------------------------------------------------------------------
# Admission control (unit level — deterministic FIFO)
# ---------------------------------------------------------------------------


class TestAdmission:
    def test_unknown_key(self):
        ctl = AdmissionController([TenantConfig("t", api_key="k")])
        assert ctl.authenticate("k").name == "t"
        with pytest.raises(UnknownTenantError):
            ctl.authenticate("wrong")

    def test_quota_then_queue_then_reject(self):
        ctl = AdmissionController(
            [TenantConfig("t", api_key="k", max_concurrent=1, max_queue=1)]
        )
        ctl.acquire("t")
        granted = threading.Event()

        def queued():
            ctl.acquire("t", timeout_s=10)
            granted.set()

        w = threading.Thread(target=queued, daemon=True)
        w.start()
        deadline = time.monotonic() + 5
        while (
            ctl.stats()["tenants"]["t"]["queued"] < 1
            and time.monotonic() < deadline
        ):
            time.sleep(0.005)
        with pytest.raises(AdmissionRejected) as exc:
            ctl.acquire("t")
        assert exc.value.reason == "quota"
        assert 1 <= exc.value.retry_after <= 60
        ctl.release("t", run_seconds=0.01)
        assert granted.wait(5)
        ctl.release("t", run_seconds=0.01)

    def test_fifo_grant_order(self):
        """Queued waiters are granted strictly in arrival order."""
        ctl = AdmissionController(
            [TenantConfig("t", api_key="k", max_concurrent=1, max_queue=8)]
        )
        ctl.acquire("t")  # saturate
        order: list[int] = []
        lock = threading.Lock()
        threads = []
        for i in range(5):
            def waiter(i=i):
                ctl.acquire("t", timeout_s=30)
                with lock:
                    order.append(i)
                ctl.release("t")

            t = threading.Thread(target=waiter, daemon=True)
            threads.append(t)
            t.start()
            # Wait until this waiter is visibly enqueued so arrival order
            # is deterministic.
            deadline = time.monotonic() + 5
            while (
                ctl.stats()["tenants"]["t"]["queued"] < i + 1
                and time.monotonic() < deadline
            ):
                time.sleep(0.002)
        ctl.release("t")  # each release grants the head; chain drains FIFO
        for t in threads:
            t.join(10)
        assert order == [0, 1, 2, 3, 4]

    def test_queue_timeout(self):
        ctl = AdmissionController(
            [TenantConfig("t", api_key="k", max_concurrent=1, max_queue=4)]
        )
        ctl.acquire("t")
        with pytest.raises(AdmissionRejected) as exc:
            ctl.acquire("t", timeout_s=0.05)
        assert exc.value.reason == "timeout"
        st = ctl.stats()["tenants"]["t"]
        assert st["queued"] == 0  # the timed-out ticket left the queue

    def test_tenants_isolated(self):
        ctl = AdmissionController(
            [
                TenantConfig("a", api_key="ka", max_concurrent=1, max_queue=0),
                TenantConfig("b", api_key="kb", max_concurrent=1, max_queue=0),
            ]
        )
        ctl.acquire("a")
        with pytest.raises(AdmissionRejected):
            ctl.acquire("a")
        ctl.acquire("b")  # a's saturation never affects b
        ctl.release("a")
        ctl.release("b")

    def test_drain_rejects_and_waits(self):
        ctl = AdmissionController([TenantConfig("t", api_key="k")])
        ctl.acquire("t")
        done = threading.Event()

        def finish():
            time.sleep(0.05)
            ctl.release("t")
            done.set()

        threading.Thread(target=finish, daemon=True).start()
        assert ctl.drain(timeout_s=5)
        assert done.is_set()
        with pytest.raises(AdmissionRejected) as exc:
            ctl.acquire("t")
        assert exc.value.reason == "draining"


# ---------------------------------------------------------------------------
# HTTP end to end
# ---------------------------------------------------------------------------


class TestGatewayHTTP:
    def test_submit_run_describe_stats(self, client):
        receipt = client.submit(DAG_BODY)
        fp = receipt["fingerprint"]
        assert len(fp) == 64 and receipt["cached"] is False
        assert receipt["backend"] == "threaded"
        assert "encode" in receipt["timings_ms"]

        again = client.submit(DAG_BODY)
        assert again["fingerprint"] == fp and again["cached"] is True

        out = client.run(fp)
        assert out["data"]["l2"]["d^work"] == [1, 2]

        batch = client.run_many(fp, [{}] * 5, max_concurrent=4)
        assert [r["data"]["l2"]["d^work"] for r in batch["results"]] == [
            [1, 2]
        ] * 5

        desc = client.describe(fp)
        assert desc["fingerprint"] == fp
        assert "exec" in desc["explain"]
        assert desc["placement"]["work"] == ["l2"]

        stats = client.stats()
        assert stats["counters"]["compiles"] == 1
        assert stats["counters"]["instances_completed"] == 6
        assert stats["cache"]["entries"] == 1
        assert stats["cache"]["hits"] >= 3  # resubmit + run + batch + desc
        assert "derive_cache" in stats and "admission" in stats

    def test_swirl_text_submission_aliases_dag(self, client):
        """A ``.swirl`` rendering of the same workflow converges on the
        same fingerprint — one compiled artifact serves both sources."""
        fp = client.submit(DAG_BODY)["fingerprint"]
        text = dumps(
            compile_submission(dict(DAG_BODY, rules=[]))[0].system
        )
        receipt = client.submit(text)  # Content-Type: text/plain
        assert receipt["fingerprint"] == fp
        assert receipt["cached"] is True  # aliased, not recompiled
        stats = client.stats()
        assert stats["counters"]["compiles"] == 1

    def test_malformed_submissions_are_400_json(self, client):
        cases = [
            ("{not json", "json"),
            (json.dumps({"frobnicate": 1}), "schema"),
            (json.dumps({"swirl": "<l,{},bogus(s)>"}), "swirl-syntax"),
            (json.dumps({"dag": {"edges": {"a": ["b"]}}}), "dag"),
            (json.dumps(dict(DAG_BODY, rules=["R99"])), "rules"),
        ]
        for raw, kind in cases:
            with pytest.raises(GatewayError) as exc:
                client._request(
                    "POST", "/v1/workflows", raw.encode()
                )
            e = exc.value
            assert e.status == 400, (raw, e.payload)
            assert e.error["type"] == "SubmissionError"
            assert e.error["kind"] == kind
            # The body is structured JSON, never a traceback.
            assert "Traceback" not in json.dumps(e.payload)

    def test_swirl_error_line_column_over_http(self, client):
        with pytest.raises(GatewayError) as exc:
            client.submit({"swirl": "<l, {d1},\n  bogus(s)>"})
        e = exc.value
        assert e.status == 400
        assert e.error["kind"] == "swirl-syntax"
        assert e.error["line"] == 2 and e.error["column"] == 3

    def test_unregistered_step_is_400(self, client):
        body = {
            "dag": {
                "edges": {"mystery": ["sink"], "sink": []},
                "mapping": {"mystery": ["l1"], "sink": ["l1"]},
            }
        }
        with pytest.raises(GatewayError) as exc:
            client.submit(body)
        assert exc.value.status == 400
        assert exc.value.error["kind"] == "steps"
        assert "mystery" in exc.value.error["message"]

    def test_unknown_fingerprint_404(self, client):
        with pytest.raises(GatewayError) as exc:
            client.run("0" * 64)
        assert exc.value.status == 404
        with pytest.raises(GatewayError) as exc:
            client.describe("f" * 64)
        assert exc.value.status == 404

    def test_unknown_route_404(self, client):
        with pytest.raises(GatewayError) as exc:
            client._request("GET", "/v2/nope")
        assert exc.value.status == 404
        assert "routes" in exc.value.error

    def test_unknown_api_key_401(self, gateway):
        with GatewayClient(gateway.url, api_key="wrong") as c:
            with pytest.raises(GatewayError) as exc:
                c.stats()
            assert exc.value.status == 401

    def test_bad_inputs_are_400(self, client):
        fp = client.submit(DAG_BODY)["fingerprint"]
        with pytest.raises(GatewayError) as exc:
            client.run(fp, {"no-colon": 1})
        assert exc.value.status == 400
        assert exc.value.error["kind"] == "inputs"
        with pytest.raises(GatewayError) as exc:
            client.run(fp, {"l9:d": 1})
        assert exc.value.status == 400
        with pytest.raises(GatewayError) as exc:
            client._request(
                "POST", f"/v1/workflows/{fp}/run_many", {"inputs": "nope"}
            )
        assert exc.value.status == 400

    def test_oversized_body_is_typed_413(self, service):
        # Regression: the cap used to be an unconfigurable 64 MB module
        # constant surfaced as a 400 "json" SubmissionError.  It is now a
        # per-gateway option with its own typed error and status.
        with Gateway(service, max_body_bytes=1024) as gw:
            big = json.dumps({"swirl": "x" * 4096}).encode()
            with GatewayClient(gw.url) as c:
                with pytest.raises(GatewayError) as exc:
                    c._request("POST", "/v1/workflows", big)
                e = exc.value
                assert e.status == 413
                assert e.error["type"] == "BodyTooLarge"
                assert e.error["limit_bytes"] == 1024
                assert e.error["content_length"] == len(big)
                assert "Traceback" not in json.dumps(e.payload)
            # The oversized request was rejected unread and its connection
            # closed; the gateway keeps serving fresh connections.
            with GatewayClient(gw.url) as c2:
                assert len(c2.submit(DAG_BODY)["fingerprint"]) == 64

    def test_body_cap_defaults_to_a_few_mb(self, service):
        from repro.serve.gateway import DEFAULT_MAX_BODY_BYTES

        with Gateway(service) as gw:
            assert gw.max_body_bytes == DEFAULT_MAX_BODY_BYTES
            assert 1024 * 1024 <= DEFAULT_MAX_BODY_BYTES <= 64 * 1024 * 1024

    def test_healthz_unauthenticated(self, gateway):
        with GatewayClient(gateway.url, api_key="not-a-key") as c:
            health = c.healthz()
            assert health["status"] == "ok"
            assert health["draining"] is False
            assert health["tenants"] == {
                "anonymous": {"queued": 0, "active": 0}
            }

    def test_concurrent_client_batches_isolated(self, gateway):
        """Several HTTP clients share one cached Executable; every batch
        observes exactly its own inputs."""
        from repro.core.graph import (
            DistributedWorkflowInstance,
            make_workflow,
        )

        svc = gateway.service
        svc.steps["ingest"] = lambda inp: {"d_ingest": inp["d_seed"]}
        svc.steps["transform"] = lambda inp: {}
        # A workflow whose source step consumes per-instance seed data
        # (the seed port has no producer step, so it is fed purely from
        # run-time initial payloads) — submitted as .swirl text.
        wf = make_workflow(
            ["ingest", "transform"],
            ["p_seed", "p_ingest"],
            [
                ("p_seed", "ingest"),
                ("ingest", "p_ingest"),
                ("p_ingest", "transform"),
            ],
        )
        inst = DistributedWorkflowInstance(
            workflow=wf,
            locations=frozenset({"l0", "l1"}),
            mapping={"ingest": ("l0",), "transform": ("l1",)},
            data=frozenset({"d_seed", "d_ingest"}),
            placement={"d_seed": "p_seed", "d_ingest": "p_ingest"},
            initial_data={"l0": frozenset({"d_seed"})},
        )
        text = dumps(swirl.trace(inst).system)
        with GatewayClient(gateway.url) as c0:
            fp = c0.submit({"swirl": text})["fingerprint"]
        out: dict[int, list] = {}
        errors: list[Exception] = []

        def worker(b):
            try:
                with GatewayClient(gateway.url) as c:
                    r = c.run_many(
                        fp,
                        [{"l0:d_seed": f"b{b}i{i}"} for i in range(4)],
                        max_concurrent=4,
                    )
                out[b] = [x["data"]["l1"]["d_ingest"] for x in r["results"]]
            except Exception as e:
                errors.append(e)

        threads = [
            threading.Thread(target=worker, args=(b,)) for b in range(4)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join(60)
        assert not errors
        for b in range(4):
            assert out[b] == [f"b{b}i{i}" for i in range(4)]


# ---------------------------------------------------------------------------
# Overload and graceful shutdown over HTTP
# ---------------------------------------------------------------------------


@pytest.mark.slow
class TestOverloadAndDrain:
    def _gateway(self, *, sleep_s, tenants):
        svc = WorkflowService(step_registry(sleep_s), tenants=tenants)
        return Gateway(svc).start()

    def test_429_with_retry_after(self):
        # Deterministic overload: ``prep`` blocks on an event, so the 2
        # in-flight + 2 queued runs cannot drain a slot early — the other
        # 6 must hit queue-full no matter how the threads are scheduled.
        release = threading.Event()
        steps = step_registry()
        sleepy_prep = steps["prep"]
        steps["prep"] = lambda inp: (release.wait(30), sleepy_prep(inp))[1]
        svc = WorkflowService(
            steps,
            tenants=[
                TenantConfig(
                    "t1", api_key="k1", max_concurrent=2, max_queue=2
                )
            ],
        )
        gw = Gateway(svc).start()
        try:
            with GatewayClient(gw.url, api_key="k1") as c0:
                fp = c0.submit(DAG_BODY)["fingerprint"]
            outcomes = {"ok": 0, "429": 0}
            lock = threading.Lock()

            def worker():
                with GatewayClient(gw.url, api_key="k1") as c:
                    try:
                        c.run(fp)
                        with lock:
                            outcomes["ok"] += 1
                    except GatewayError as e:
                        assert e.status == 429
                        assert e.retry_after >= 1
                        assert e.error["reason"] == "quota"
                        with lock:
                            outcomes["429"] += 1

            threads = [
                threading.Thread(target=worker) for _ in range(10)
            ]
            for t in threads:
                t.start()
            deadline = time.monotonic() + 20
            while time.monotonic() < deadline:
                with lock:
                    if outcomes["429"] == 6:
                        break
                time.sleep(0.01)
            release.set()
            for t in threads:
                t.join(30)
            # 2 in flight + 2 queued succeed; the rest are shed — and
            # every admitted run completed (nothing dropped).
            assert outcomes == {"ok": 4, "429": 6}
            with GatewayClient(gw.url, api_key="k1") as c0:
                s = c0.stats()
            assert s["counters"]["rejected"] == 6
            assert s["counters"]["instances_completed"] == 4
            assert s["counters"]["instances_failed"] == 0
            assert s["admission"]["tenants"]["t1"]["rejected"] == 6
        finally:
            gw.close(drain_timeout_s=5)

    def test_per_tenant_isolation_over_http(self):
        gw = self._gateway(
            sleep_s=0.1,
            tenants=[
                TenantConfig(
                    "busy", api_key="kb", max_concurrent=1, max_queue=0
                ),
                TenantConfig(
                    "idle", api_key="ki", max_concurrent=2, max_queue=2
                ),
            ],
        )
        try:
            with GatewayClient(gw.url, api_key="kb") as c:
                fp = c.submit(DAG_BODY)["fingerprint"]
            hold = threading.Thread(
                target=lambda: GatewayClient(gw.url, api_key="kb").run(fp)
            )
            hold.start()
            time.sleep(0.03)  # let the busy tenant saturate its 1 slot
            with GatewayClient(gw.url, api_key="kb") as c:
                with pytest.raises(GatewayError) as exc:
                    c.run(fp)
                assert exc.value.status == 429
            # The other tenant is untouched by busy's saturation.
            with GatewayClient(gw.url, api_key="ki") as c:
                assert c.run(fp)["data"]["l2"]["d^work"] == [1, 2]
            hold.join(30)
        finally:
            gw.close(drain_timeout_s=5)

    def test_graceful_drain_finishes_inflight(self):
        gw = self._gateway(sleep_s=0.2, tenants=None)
        with GatewayClient(gw.url) as c:
            fp = c.submit(DAG_BODY)["fingerprint"]
        done: list[dict] = []

        def inflight():
            with GatewayClient(gw.url) as c:
                done.append(c.run(fp))

        t = threading.Thread(target=inflight)
        t.start()
        time.sleep(0.05)  # the run is admitted and sleeping in its step
        assert gw.close(drain_timeout_s=10)  # True ⇒ nothing dropped
        t.join(10)
        assert done and done[0]["data"]["l2"]["d^work"] == [1, 2]

    def test_draining_rejects_new_work_with_503(self):
        gw = self._gateway(sleep_s=0.0, tenants=None)
        svc = gw.service
        with GatewayClient(gw.url) as c:
            fp = c.submit(DAG_BODY)["fingerprint"]
            svc.drain(timeout_s=5)
            health = c.healthz()
            assert health["status"] == "draining"
            assert health["draining"] is True
            with pytest.raises(GatewayError) as exc:
                c.submit(DAG_BODY)
            assert exc.value.status == 503
            with pytest.raises(GatewayError) as exc:
                c.run(fp)
            assert exc.value.status == 503
        gw.close(drain_timeout_s=1)


# ---------------------------------------------------------------------------
# Observability: /v1/metrics, trace ids, drain-aware healthz
# ---------------------------------------------------------------------------


class TestObservability:
    def test_metrics_unauthenticated_prometheus_text(self, gateway):
        import http.client

        with GatewayClient(gateway.url, api_key="not-a-key") as c:
            fp_err = None
            try:
                c.describe("0" * 64)
            except GatewayError as e:
                fp_err = e
            assert fp_err is not None and fp_err.status == 401
            text = c.metrics()
        assert text.endswith("\n")
        # Exposition-format shape: every sample line's metric appears
        # under a matching # TYPE header.
        typed = {}
        for line in text.splitlines():
            if line.startswith("# TYPE "):
                _, _, name, kind = line.split(" ")
                typed[name] = kind
        assert typed["gateway_requests_total"] == "counter"
        assert typed["gateway_request_seconds"] == "histogram"
        assert typed["tenant_queue_depth"] == "gauge"
        assert typed["plan_cache_hit_rate"] == "gauge"
        for line in text.splitlines():
            if not line or line.startswith("#"):
                continue
            name = line.split("{")[0].split(" ")[0]
            base = name
            for suffix in ("_bucket", "_sum", "_count"):
                if name.endswith(suffix) and name[: -len(suffix)] in typed:
                    base = name[: -len(suffix)]
            assert base in typed, f"untyped sample {name!r}"
        # Content type is the Prometheus text exposition format.
        host, port = gateway.address
        conn = http.client.HTTPConnection(host, port, timeout=10)
        conn.request("GET", "/v1/metrics")
        resp = conn.getresponse()
        resp.read()
        assert resp.getheader("Content-Type").startswith(
            "text/plain; version=0.0.4"
        )
        conn.close()

    def test_metrics_track_requests_and_cache(self, gateway):
        with GatewayClient(gateway.url) as c:
            fp = c.submit(DAG_BODY)["fingerprint"]
            c.submit(DAG_BODY)  # cache hit
            c.run(fp)
            text = c.metrics()
        samples = {}
        for line in text.splitlines():
            if line and not line.startswith("#"):
                key, value = line.rsplit(" ", 1)
                samples[key] = float(value)
        assert (
            samples[
                'gateway_requests_total{method="POST",route="submit",'
                'status="200"}'
            ]
            == 2
        )
        assert (
            samples[
                'gateway_requests_total{method="POST",route="run",'
                'status="200"}'
            ]
            == 1
        )
        assert samples["plan_cache_hits_total"] >= 1
        assert samples['service_operations_total{kind="submissions"}'] == 2
        assert samples['service_operations_total{kind="runs"}'] == 1
        assert samples['gateway_request_seconds_count{route="submit"}'] == 2

    def test_metrics_count_429_per_tenant(self):
        tenants = [
            TenantConfig("tiny", api_key="kt", max_concurrent=1, max_queue=0)
        ]
        svc = WorkflowService(step_registry(sleep_s=0.4), tenants=tenants)
        with Gateway(svc) as gw:
            with GatewayClient(gw.url, api_key="kt") as c:
                fp = c.submit(DAG_BODY)["fingerprint"]
                hold = threading.Thread(target=lambda: c2.run(fp))
                with GatewayClient(gw.url, api_key="kt") as c2:
                    hold.start()
                    time.sleep(0.1)  # c2 occupies tiny's only slot
                    with pytest.raises(GatewayError) as exc:
                        c.run(fp)
                    assert exc.value.status == 429
                    text = c.metrics()
                    hold.join(30)
        assert 'tenant_rejected_total{tenant="tiny"} 1' in text
        assert 'tenant_active_runs{tenant="tiny"} 1' in text

    def test_trace_id_generated_and_echoed(self, gateway):
        import http.client

        host, port = gateway.address
        conn = http.client.HTTPConnection(host, port, timeout=10)
        conn.request("GET", "/v1/healthz")
        resp = conn.getresponse()
        resp.read()
        generated = resp.getheader("X-Trace-Id")
        assert generated and len(generated) == 16
        conn.request(
            "GET", "/v1/healthz", headers={"X-Trace-Id": "req-12345"}
        )
        resp = conn.getresponse()
        resp.read()
        assert resp.getheader("X-Trace-Id") == "req-12345"
        conn.close()

    def test_trace_id_in_error_bodies(self, client):
        with pytest.raises(GatewayError) as exc:
            client.describe("0" * 64)
        trace_id = exc.value.error["trace_id"]
        assert trace_id and isinstance(trace_id, str)
        with pytest.raises(GatewayError) as exc:
            client._request("POST", "/v1/workflows", {"bad": True})
        assert exc.value.error["trace_id"]

    def test_healthz_reports_queue_depths_per_tenant(self):
        tenants = [
            TenantConfig("busy", api_key="kb", max_concurrent=1, max_queue=4),
            TenantConfig("idle", api_key="ki"),
        ]
        svc = WorkflowService(step_registry(sleep_s=0.4), tenants=tenants)
        with Gateway(svc) as gw:
            with GatewayClient(gw.url, api_key="kb") as c:
                fp = c.submit(DAG_BODY)["fingerprint"]

            def run_one():
                with GatewayClient(gw.url, api_key="kb") as c2:
                    c2.run(fp)

            threads = [
                threading.Thread(target=run_one) for _ in range(3)
            ]
            for t in threads:
                t.start()
            time.sleep(0.15)  # 1 active + 2 queued on "busy"
            with GatewayClient(gw.url) as anon:
                health = anon.healthz()
            for t in threads:
                t.join(30)
        assert health["draining"] is False
        busy = health["tenants"]["busy"]
        assert busy["active"] == 1
        assert busy["queued"] == 2
        assert health["tenants"]["idle"] == {"queued": 0, "active": 0}
