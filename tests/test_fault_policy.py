"""Uniform fault-policy layer: one ``FaultPolicy``, four backends, one gateway.

The conformance sweep is the headline: the same seeded DAGs with injected
transient step failures (and a delayed straggler) run on **every registered
backend** under the same ``policy=FaultPolicy(...)`` lowering option, and
must produce identical final stores while each backend reports the retries
it performed.  Around it, targeted regressions for each mechanism:

* capped exponential full-jitter backoff, deterministic under a seeded rng;
* the single documented heartbeat default (``fault.py`` vs the old 60s
  construction in ``central.py``);
* shared interpreter helpers (``call_with_timeout`` / ``StepGuard`` /
  ``Deadline``);
* per-backend specifics — inprocess speculation + run deadline, threaded
  crash-recovery replay, multiprocess worker retry and the heartbeat that
  declares a *delayed* (not killed) straggler dead and folds it into
  elastic recovery;
* the transport's typed :class:`AckTimeout` (endpoint / seq / attempts);
* serving: ``deadline_s`` → typed 504 within 2× the deadline with the
  admission slot released, and per-tenant server-side retries.
"""

from __future__ import annotations

import random
import threading
import time

import pytest
from conftest import identity_step_fns

from repro import swirl
from repro.backends import available_backends
from repro.core.graph import DistributedWorkflowInstance, make_workflow
from repro.exec import (
    Deadline,
    FaultPolicy,
    RunDeadlineExceeded,
    StepGuard,
    StepTimeoutError,
)
from repro.exec.interp import call_with_timeout
from repro.serve import (
    Gateway,
    GatewayClient,
    GatewayError,
    TenantConfig,
    WorkflowService,
)
from repro.workflow import (
    DEFAULT_HEARTBEAT_TIMEOUT_S,
    AckTimeout,
    FlakyFn,
    HeartbeatMonitor,
    RetryPolicy,
    SlowFn,
    SlowOnceAcrossProcesses,
    TransientError,
)
from repro.workflow.transport import SocketTransport, socket_addresses

#: Generous outer timeouts so a loaded CI machine cannot fake a hang.
BACKEND_OPTIONS = {
    "threaded": {"timeout_s": 60},
    "multiprocess": {"timeout_s": 120},
}


def diamond_instance() -> DistributedWorkflowInstance:
    """The chaos-benchmark diamond: pre → {a, b} → join → out on 3 nodes."""
    steps = ["c_pre", "c_a", "c_b", "c_join", "c_out"]
    ports = [f"p{s}" for s in steps]
    deps = [(s, f"p{s}") for s in steps]
    deps += [
        ("pc_pre", "c_a"),
        ("pc_pre", "c_b"),
        ("pc_a", "c_join"),
        ("pc_b", "c_join"),
        ("pc_join", "c_out"),
    ]
    return DistributedWorkflowInstance(
        workflow=make_workflow(steps, ports, deps),
        locations=frozenset({"n0", "n1", "n2"}),
        mapping={
            "c_pre": ("n0",),
            "c_a": ("n1",),
            "c_b": ("n2",),
            "c_join": ("n1",),
            "c_out": ("n0",),
        },
        data=frozenset({f"d{s}" for s in steps}),
        placement={f"d{s}": f"p{s}" for s in steps},
        initial_data={},
    )


def marker_fn(step: str):
    def fn(inputs):
        return {f"d{step}": sorted(inputs) + [step]}

    return fn


def marker_fns(inst: DistributedWorkflowInstance):
    return {s: marker_fn(s) for s in inst.workflow.steps}


def chain_instance() -> DistributedWorkflowInstance:
    """A single-location 3-step chain (no blocked peers on failure)."""
    steps = ["u", "v", "w"]
    ports = [f"p{s}" for s in steps]
    deps = [(s, f"p{s}") for s in steps] + [("pu", "v"), ("pv", "w")]
    return DistributedWorkflowInstance(
        workflow=make_workflow(steps, ports, deps),
        locations=frozenset({"l0"}),
        mapping={s: ("l0",) for s in steps},
        data=frozenset({f"d{s}" for s in steps}),
        placement={f"d{s}": f"p{s}" for s in steps},
        initial_data={},
    )


def policy_counts(result) -> dict:
    """Normalise each backend's policy stats to one ``{retries, timeouts}``."""
    stats = result.stats
    if hasattr(stats, "retries"):  # the inprocess RunStats dataclass
        return {"retries": stats.retries, "timeouts": stats.timeouts}
    return dict(stats.get("policy") or {})


# ---------------------------------------------------------------------------
# FaultPolicy construction + the single heartbeat default (satellite 1)
# ---------------------------------------------------------------------------


class TestFaultPolicy:
    def test_zero_policy_is_inert(self):
        p = FaultPolicy()
        assert not p.active
        assert p.retry_policy() is None
        assert p.speculation_policy() is None

    @pytest.mark.parametrize(
        "kwargs",
        [
            {"max_retries": -1},
            {"backoff_s": -0.1},
            {"timeout_s": 0},
            {"speculation_factor": 0.0},
            {"deadline_s": -2},
            {"max_speculative": 0},
            {"heartbeat_timeout_s": 0},
        ],
    )
    def test_validation(self, kwargs):
        with pytest.raises(ValueError):
            FaultPolicy(**kwargs)

    def test_engine_constructors_inherit_fields(self):
        p = FaultPolicy(
            max_retries=2,
            backoff_s=0.5,
            backoff_cap_s=4.0,
            speculation_factor=2.5,
            max_speculative=3,
            heartbeat_timeout_s=7.0,
        )
        rp = p.retry_policy()
        assert (rp.max_retries, rp.backoff_s, rp.backoff_cap_s) == (2, 0.5, 4.0)
        sp = p.speculation_policy()
        assert sp.enabled and sp.factor == 2.5 and sp.max_speculative == 3
        assert p.heartbeat_monitor().timeout_s == 7.0

    def test_heartbeat_default_single_home(self):
        # Regression: fault.py used to default 5.0s while central.py
        # constructed 60.0s — now both read one documented constant.
        assert (
            HeartbeatMonitor().timeout_s
            == FaultPolicy().heartbeat_timeout_s
            == DEFAULT_HEARTBEAT_TIMEOUT_S
        )

    def test_policy_crosses_pickle(self):
        import pickle

        p = FaultPolicy(max_retries=1, timeout_s=2.0)
        assert pickle.loads(pickle.dumps(p)) == p


# ---------------------------------------------------------------------------
# Exponential full-jitter backoff (satellite 2)
# ---------------------------------------------------------------------------


class TestRetryBackoff:
    def test_zero_base_never_sleeps(self):
        assert RetryPolicy(backoff_s=0.0).sleep_s(5) == 0.0

    def test_exponential_ceiling_with_cap(self):
        rp = RetryPolicy(backoff_s=1.0, backoff_cap_s=4.0, rng=random.Random(1))
        for attempt, ceiling in [(0, 1.0), (1, 2.0), (2, 4.0), (3, 4.0), (8, 4.0)]:
            for _ in range(20):
                s = rp.sleep_s(attempt)
                assert 0.0 <= s <= ceiling

    def test_deterministic_under_seeded_rng(self):
        a = RetryPolicy(backoff_s=0.25, rng=random.Random(42))
        b = RetryPolicy(backoff_s=0.25, rng=random.Random(42))
        assert [a.sleep_s(n) for n in range(6)] == [
            b.sleep_s(n) for n in range(6)
        ]

    def test_jitter_actually_varies(self):
        rp = RetryPolicy(backoff_s=1.0, rng=random.Random(0))
        assert len({rp.sleep_s(3) for _ in range(8)}) > 1


# ---------------------------------------------------------------------------
# Shared interpreter helpers
# ---------------------------------------------------------------------------


class TestInterpHelpers:
    def test_call_with_timeout_passthrough(self):
        assert call_with_timeout(lambda: 7, None, "s") == 7
        assert call_with_timeout(lambda: 7, 5.0, "s") == 7

    def test_call_with_timeout_raises_typed(self):
        with pytest.raises(StepTimeoutError) as ei:
            call_with_timeout(lambda: time.sleep(5), 0.05, "slow")
        assert ei.value.step == "slow"
        assert ei.value.timeout_s == 0.05
        assert isinstance(ei.value, TransientError)  # consumes a retry

    def test_call_with_timeout_propagates_errors(self):
        with pytest.raises(KeyError):
            call_with_timeout(lambda: {}["x"], 5.0, "s")

    def test_step_guard_counts_and_callbacks(self):
        seen = []
        guard = StepGuard(
            FaultPolicy(max_retries=2, timeout_s=0.2),
            on_retry=lambda step, n, e: seen.append(("retry", step, n)),
            on_timeout=lambda step: seen.append(("timeout", step)),
        )
        flaky = FlakyFn(lambda inputs: {"d": 1}, failures=1)
        assert guard.fire("s", lambda: flaky({})) == {"d": 1}
        slow = SlowFn(lambda inputs: {"d": 2}, delay_s=2.0, slow_calls=1)
        assert guard.fire("t", lambda: slow({})) == {"d": 2}
        assert guard.counts() == {"retries": 2, "timeouts": 1}
        assert ("retry", "s", 0) in seen and ("timeout", "t") in seen

    def test_step_guard_lets_budget_exhaustion_escape(self):
        guard = StepGuard(FaultPolicy(max_retries=1))
        flaky = FlakyFn(lambda inputs: {"d": 1}, failures=5)
        with pytest.raises(TransientError):
            guard.fire("s", lambda: flaky({}))

    def test_deadline(self):
        d = Deadline(None)
        assert d.remaining() is None and not d.expired()
        d.check()  # no-op
        clock = iter([0.0, 0.05, 0.2, 0.2, 0.2, 0.2]).__next__
        d = Deadline(0.1, clock=clock)
        assert d.remaining() == pytest.approx(0.05)
        assert d.expired()
        with pytest.raises(RunDeadlineExceeded):
            d.check()


# ---------------------------------------------------------------------------
# Cross-backend conformance sweep (satellite 4)
# ---------------------------------------------------------------------------


def _sweep_instance(seed: int) -> DistributedWorkflowInstance:
    from test_differential import random_instance

    return random_instance(random.Random(seed))


class TestConformanceSweep:
    @pytest.mark.parametrize("seed", [11, 23, 37])
    def test_flaky_steps_agree_across_backends(self, seed):
        """Same DAG + injected transient failures: identical stores and
        ≥1 reported retry on every registered backend."""
        inst = _sweep_instance(seed)
        plan = swirl.trace(inst).optimize(("R1R2", "R3"))
        policy = FaultPolicy(max_retries=3)
        results = {}
        for backend in available_backends():
            fns = {
                s: FlakyFn(fn, failures=1)
                for s, fn in identity_step_fns(inst).items()
            }
            res = (
                plan.lower(backend, policy=policy, **BACKEND_OPTIONS.get(backend, {}))
                .compile(fns)
                .run()
            )
            results[backend] = res
            assert policy_counts(res)["retries"] >= 1, (
                f"{backend} reported no retries"
            )
        reference = available_backends()[0]
        for backend, res in results.items():
            assert res.data == results[reference].data, (
                f"{backend} diverged from {reference} under the fault policy"
            )

    def test_delayed_straggler_agrees_across_backends(self):
        """One slow step + per-step timeout: every backend times the
        straggling attempt out, retries it, and agrees on the store."""
        inst = diamond_instance()
        plan = swirl.trace(inst).optimize(("R1R2", "R3"))
        policy = FaultPolicy(max_retries=2, timeout_s=0.25)
        results = {}
        for backend in available_backends():
            fns = marker_fns(inst)
            fns["c_join"] = SlowFn(
                marker_fn("c_join"), delay_s=1.5, slow_calls=1
            )
            res = (
                plan.lower(backend, policy=policy, **BACKEND_OPTIONS.get(backend, {}))
                .compile(fns)
                .run()
            )
            results[backend] = res
            counts = policy_counts(res)
            assert counts["timeouts"] >= 1, f"{backend} reported no timeout"
            assert counts["retries"] >= 1, f"{backend} reported no retry"
        reference = available_backends()[0]
        for backend, res in results.items():
            assert res.data == results[reference].data

    def test_policy_is_a_known_option_everywhere(self):
        inst = chain_instance()
        plan = swirl.trace(inst)
        for backend in available_backends():
            lowered = plan.lower(
                backend,
                policy=FaultPolicy(max_retries=1),
                **BACKEND_OPTIONS.get(backend, {}),
            )
            res = lowered.compile(marker_fns(inst)).run()
            assert res.data["l0"]["dw"] == ["dv", "w"]


# ---------------------------------------------------------------------------
# Per-backend specifics
# ---------------------------------------------------------------------------


class TestInprocessPolicy:
    def test_speculation_win_counted(self):
        inst = chain_instance()
        plan = swirl.trace(inst)
        fns = marker_fns(inst)
        fns["v"] = SlowFn(marker_fn("v"), delay_s=1.0, slow_calls=1)
        res = (
            plan.lower(
                "inprocess",
                policy=FaultPolicy(speculation_factor=2.0),
                expected_s={"v": 0.02},
            )
            .compile(fns)
            .run()
        )
        assert res.stats.speculations >= 1
        assert res.data["l0"]["dw"] == ["dv", "w"]

    def test_run_deadline_raises_typed(self):
        inst = chain_instance()
        plan = swirl.trace(inst)
        fns = marker_fns(inst)
        fns["v"] = SlowFn(marker_fn("v"), delay_s=5.0, slow_calls=1)
        lowered = plan.lower(
            "inprocess", policy=FaultPolicy(deadline_s=0.2)
        )
        t0 = time.monotonic()
        with pytest.raises(RunDeadlineExceeded):
            lowered.compile(fns).run()
        assert time.monotonic() - t0 < 4.0


class TestThreadedPolicy:
    def test_crash_recovery_replays_location(self):
        """A location thread dying mid-program (retry budget exhausted on
        the first call only) is replayed from its op log."""
        inst = chain_instance()
        plan = swirl.trace(inst)
        fns = marker_fns(inst)
        # failures=1 with max_retries=0: the first fire kills the location
        # thread; the replay's fresh fire succeeds.
        fns["v"] = FlakyFn(marker_fn("v"), failures=1)
        res = (
            plan.lower("threaded", timeout_s=30, policy=FaultPolicy())
            .compile(fns)
            .run()
        )
        recoveries = res.stats.get("recoveries") or []
        assert any(r["mode"] == "replay" for r in recoveries)
        assert res.data["l0"]["dw"] == ["dv", "w"]

    def test_deadline_raises_typed(self):
        inst = chain_instance()
        plan = swirl.trace(inst)
        fns = marker_fns(inst)
        fns["v"] = SlowFn(marker_fn("v"), delay_s=5.0, slow_calls=1)
        lowered = plan.lower(
            "threaded", timeout_s=30, policy=FaultPolicy(deadline_s=0.2)
        )
        with pytest.raises(RunDeadlineExceeded):
            lowered.compile(fns).run()


class TestMultiprocessPolicy:
    def test_worker_side_retry(self):
        inst = diamond_instance()
        plan = swirl.trace(inst).optimize(("R1R2", "R3"))
        fns = marker_fns(inst)
        fns["c_a"] = FlakyFn(marker_fn("c_a"), failures=1)
        res = (
            plan.lower(
                "multiprocess",
                timeout_s=60,
                policy=FaultPolicy(max_retries=2),
            )
            .compile(fns)
            .run()
        )
        assert res.stats["policy"]["retries"] >= 1
        assert res.data["n0"]["dc_out"] == ["dc_join", "c_out"]

    @pytest.mark.parametrize("mode", ["spare", "fold"])
    def test_heartbeat_declares_delayed_straggler(self, mode, tmp_path):
        """A *delayed* worker (never killed) is declared dead by the
        progress heartbeat and elastic recovery reruns its work — with the
        final store identical to a fault-free run modulo the renaming."""
        inst = diamond_instance()
        plan = swirl.trace(inst).optimize(("R1R2", "R3"))
        reference = (
            plan.lower("multiprocess", timeout_s=60)
            .compile(marker_fns(inst))
            .run()
        )
        fns = marker_fns(inst)
        fns["c_join"] = SlowOnceAcrossProcesses(
            marker_fn("c_join"),
            flag_path=str(tmp_path / f"straggle-{mode}"),
            delay_s=30.0,
        )
        policy = FaultPolicy(
            heartbeat_interval_s=0.2, heartbeat_timeout_s=1.0
        )
        res = (
            plan.lower(
                "multiprocess", timeout_s=60, policy=policy, recover=mode
            )
            .compile(fns)
            .run()
        )
        assert res.stats["policy"]["heartbeat_deaths"] == 1
        (event,) = res.stats["recoveries"]
        assert event["declared_by"] == "heartbeat"
        assert event["mode"] == mode
        # Fault-free data modulo the event's renaming: every (datum,
        # payload) present in the reference survives at the renamed
        # location, and no datum changed value anywhere.
        renaming = event["renaming"]
        merged: dict[str, dict] = {}
        for loc, store in reference.data.items():
            merged.setdefault(renaming.get(loc, loc), {}).update(store)
        for loc, store in merged.items():
            for datum, value in store.items():
                assert res.data[loc][datum] == value, (loc, datum)
        for loc, store in res.data.items():
            for datum, value in store.items():
                assert merged[loc][datum] == value, (loc, datum)


class TestJaxPolicy:
    def test_retry_and_deadline(self):
        if "jax" not in available_backends():
            pytest.skip("jax backend not registered")
        inst = chain_instance()
        plan = swirl.trace(inst)
        fns = marker_fns(inst)
        fns["v"] = FlakyFn(marker_fn("v"), failures=1)
        res = (
            plan.lower("jax", policy=FaultPolicy(max_retries=1))
            .compile(fns)
            .run()
        )
        assert res.stats["policy"]["retries"] == 1
        fns = marker_fns(inst)
        fns["v"] = SlowFn(marker_fn("v"), delay_s=5.0, slow_calls=1)
        lowered = plan.lower("jax", policy=FaultPolicy(deadline_s=0.2))
        with pytest.raises(RunDeadlineExceeded):
            lowered.compile(fns).run()


# ---------------------------------------------------------------------------
# Transport: typed AckTimeout (satellite 3)
# ---------------------------------------------------------------------------


class TestAckTimeout:
    def test_exhausted_resends_raise_typed(self, tmp_path):
        locations = ["a", "b"]
        t = SocketTransport(
            socket_addresses(locations, base_dir=tmp_path),
            serve=locations,
            ack_timeout=0.05,
            max_sends=3,
            connect_timeout=5.0,
            drop_prob=1.0,  # the wire eats every frame — no ack, ever
            seed=1,
        )
        try:
            with pytest.raises(AckTimeout) as ei:
                t.send(("a", "b", "p"), "d", 1)
            err = ei.value
            assert err.endpoint == ("a", "b", "p")
            assert err.attempts == 3
            assert err.seq == 1
            from repro.workflow import ChannelClosed

            assert isinstance(err, ChannelClosed)  # old handlers still match
            stats = t.stats()
            assert stats["resends"] == 2  # attempts - 1 re-sends
            assert stats["delivered"] == 0
        finally:
            t.close()


# ---------------------------------------------------------------------------
# Serving: deadline_s → 504, tenant retries (tentpole serving propagation)
# ---------------------------------------------------------------------------

EDGES = {"prep": ["work"], "work": ["sink"], "sink": []}
SINGLE_MAPPING = {"prep": ["l1"], "work": ["l1"], "sink": ["l1"]}
DAG_BODY = {"dag": {"edges": EDGES, "mapping": SINGLE_MAPPING}}


def _registry(prep):
    return {
        "prep": prep,
        "work": lambda inp: {"d^work": inp["d^prep"] + [2]},
        "sink": lambda inp: {},
    }


class TestServingDeadline:
    def test_deadline_maps_to_typed_504_and_releases_slot(self):
        def slow_prep(inp):
            time.sleep(5.0)
            return {"d^prep": [1]}

        service = WorkflowService(
            _registry(slow_prep),
            tenants=[TenantConfig("t", api_key="k", max_concurrent=1)],
            lower_options={"timeout_s": 30},
        )
        with Gateway(service) as gw, GatewayClient(gw.url, api_key="k") as c:
            fp = c.submit(DAG_BODY)["fingerprint"]
            t0 = time.monotonic()
            with pytest.raises(GatewayError) as ei:
                c.run(fp, deadline_s=0.4)
            elapsed = time.monotonic() - t0
            assert ei.value.status == 504
            assert ei.value.error["type"] == "DeadlineExceeded"
            assert ei.value.error["deadline_s"] == 0.4
            assert elapsed < 0.8  # within 2× the deadline
            # The admission slot is free again: with max_concurrent=1 a
            # leaked in-flight run would make this queue behind the
            # abandoned one for its full 5s sleep.
            depths = service.admission.queue_depths()["t"]
            assert depths["active"] == 0 and depths["queued"] == 0
            counters = service.stats()["counters"]
            assert counters["deadline_aborts"] == 1

    def test_deadline_header_honored(self):
        def slow_prep(inp):
            time.sleep(5.0)
            return {"d^prep": [1]}

        service = WorkflowService(
            _registry(slow_prep), lower_options={"timeout_s": 30}
        )
        with Gateway(service) as gw:
            import http.client
            import json as _json

            conn = http.client.HTTPConnection(*gw.address, timeout=30)
            try:
                conn.request(
                    "POST",
                    f"/v1/workflows/{_submit(gw)}/run",
                    body=b'{"inputs": {}}',
                    headers={
                        "X-API-Key": "",
                        "Content-Type": "application/json",
                        "X-Deadline-S": "0.3",
                    },
                )
                resp = conn.getresponse()
                body = _json.loads(resp.read())
                assert resp.status == 504
                assert body["error"]["type"] == "DeadlineExceeded"
            finally:
                conn.close()

    def test_fast_run_unaffected_by_deadline(self):
        service = WorkflowService(
            _registry(lambda inp: {"d^prep": [1]}),
            lower_options={"timeout_s": 30},
        )
        with Gateway(service) as gw, GatewayClient(gw.url) as c:
            fp = c.submit(DAG_BODY)["fingerprint"]
            out = c.run(fp, deadline_s=30.0)
            assert out["data"]["l1"]["d^work"] == [1, 2]
            assert service.stats()["counters"]["deadline_aborts"] == 0

    def test_invalid_deadline_is_typed_400(self):
        service = WorkflowService(
            _registry(lambda inp: {"d^prep": [1]}),
            lower_options={"timeout_s": 30},
        )
        with Gateway(service) as gw, GatewayClient(gw.url) as c:
            fp = c.submit(DAG_BODY)["fingerprint"]
            for bad in (-1, 0, "soon"):
                with pytest.raises(GatewayError) as ei:
                    c.run(fp, deadline_s=bad)
                assert ei.value.status == 400
                assert ei.value.error["kind"] == "deadline"


def _submit(gw) -> str:
    with GatewayClient(gw.url) as c:
        return c.submit(DAG_BODY)["fingerprint"]


class TestServingTenantRetry:
    def test_recoverable_failure_retried_per_tenant_policy(self):
        service = WorkflowService(
            _registry(FlakyFn(lambda inp: {"d^prep": [1]}, failures=1)),
            tenants=[TenantConfig("t", api_key="k", max_retries=2)],
            lower_options={"timeout_s": 10},
        )
        with Gateway(service) as gw, GatewayClient(gw.url, api_key="k") as c:
            fp = c.submit(DAG_BODY)["fingerprint"]
            out = c.run(fp)
            assert out["data"]["l1"]["d^work"] == [1, 2]
            counters = service.stats()["counters"]
            assert counters["run_retries"] == 1
            assert counters["instances_completed"] == 1

    def test_zero_retry_tenant_sees_the_failure(self):
        service = WorkflowService(
            _registry(FlakyFn(lambda inp: {"d^prep": [1]}, failures=1)),
            lower_options={"timeout_s": 10},
        )
        with Gateway(service) as gw, GatewayClient(gw.url) as c:
            fp = c.submit(DAG_BODY)["fingerprint"]
            with pytest.raises(GatewayError) as ei:
                c.run(fp)
            assert ei.value.status == 500
            assert service.stats()["counters"]["run_retries"] == 0

    def test_tenant_config_validates_max_retries(self):
        with pytest.raises(ValueError, match="max_retries"):
            TenantConfig("t", api_key="k", max_retries=-1)
