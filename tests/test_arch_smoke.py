"""Per-architecture smoke tests: reduced config, one train + decode step on
CPU, asserting shapes and no NaNs.  Full configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.models import Model, padded_vocab


def _batch(cfg, b, l, key=1):
    tokens = jax.random.randint(jax.random.key(key), (b, l), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.is_encoder_decoder:
        batch["src_embeds"] = (
            jax.random.normal(
                jax.random.key(key + 1), (b, cfg.frontend_len, cfg.d_model)
            ) * 0.1
        ).astype(jnp.dtype(cfg.dtype))
    if cfg.frontend == "vision":
        batch["patch_embeds"] = (
            jax.random.normal(
                jax.random.key(key + 2), (b, cfg.frontend_len, cfg.d_model)
            ) * 0.1
        ).astype(jnp.dtype(cfg.dtype))
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_forward_and_train(arch):
    cfg = get_config(arch, smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    b, l = 2, 16
    batch = _batch(cfg, b, l)

    logits, aux = model.forward(
        params, batch["tokens"],
        src_embeds=batch.get("src_embeds"),
        patch_embeds=batch.get("patch_embeds"),
    )
    exp_len = l + (cfg.frontend_len if cfg.frontend == "vision" else 0)
    assert logits.shape == (b, exp_len, padded_vocab(cfg))
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
        params, batch
    )
    assert jnp.isfinite(loss), arch
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in jax.tree.leaves(grads))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_decode(arch):
    cfg = get_config(arch, smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    b = 2
    batch = _batch(cfg, b, 8)
    cache = model.init_cache(b, 32)
    logits, cache = model.prefill(
        params, batch["tokens"], cache,
        src_embeds=batch.get("src_embeds"),
        patch_embeds=batch.get("patch_embeds"),
    )
    assert logits.shape[0] == b
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    for _ in range(3):
        logits, cache = model.decode_step(params, tok, cache)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32)))), arch
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_full_config_exactness(arch):
    """The registered full config matches the assignment row."""
    cfg = get_config(arch)
    assignment = {
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "gemma2-27b": (46, 4608, 32, 16, 36864, 256000),
        "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
        "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
        "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "deepseek-moe-16b": (28, 2048, 16, 16, 10944, 102400),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
    }[arch]
    assert cfg.n_layers == assignment[0]
    assert cfg.d_model == assignment[1]
    assert cfg.n_heads == assignment[2]
    assert cfg.n_kv_heads == assignment[3]
    assert cfg.d_ff == assignment[4]
    assert cfg.vocab == assignment[5]


def test_moe_configs():
    g = get_config("granite-moe-1b-a400m").moe
    assert (g.n_experts, g.top_k, g.d_expert) == (32, 8, 512)
    d = get_config("deepseek-moe-16b").moe
    assert (d.n_experts, d.top_k, d.n_shared, d.d_expert) == (64, 6, 2, 1408)
    j = get_config("jamba-v0.1-52b").moe
    assert (j.n_experts, j.top_k) == (16, 2)


def test_jamba_interleave():
    cfg = get_config("jamba-v0.1-52b")
    seq = cfg.layer_seq()
    assert len(seq) == 32
    assert sum(1 for m, _ in seq if m == "attn") == 4  # 1:7 attn:mamba
    assert sum(1 for _, f in seq if f == "moe") == 16  # every other layer
