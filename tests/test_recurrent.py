"""Recurrent mixers: chunked parallel forms ≡ sequential references."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ModelConfig, SSMCfg
from repro.models.recurrent import (
    MLSTMState,
    init_mamba,
    init_mlstm,
    init_mlstm_state,
    init_slstm,
    mamba_mix,
    mlstm_mix,
    slstm_mix,
)


def _cfg(chunk, d=32, heads=2, ds=4):
    return ModelConfig(
        name="t", n_layers=2, d_model=d, n_heads=heads, n_kv_heads=heads,
        head_dim=d // heads, d_ff=0, vocab=64, dtype="float32", remat=False,
        ssm=SSMCfg(d_state=ds, d_conv=4, expand=2, chunk=chunk),
    )


class TestMamba:
    @pytest.mark.parametrize("chunk", [1, 3, 8, 64])
    def test_chunking_invariance(self, chunk):
        """Any chunk size gives identical outputs (carried state is exact)."""
        cfg_ref = _cfg(chunk=64)
        cfg = _cfg(chunk=chunk)
        p = init_mamba(jax.random.key(0), cfg_ref, jnp.float32)
        x = jax.random.normal(jax.random.key(1), (2, 16, cfg_ref.d_model)) * 0.5
        y_ref, st_ref = mamba_mix(cfg_ref, p, x)
        y, st = mamba_mix(cfg, p, x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)
        np.testing.assert_allclose(
            np.asarray(st.ssm), np.asarray(st_ref.ssm), atol=1e-4
        )

    def test_streaming_equals_batch(self):
        """Feeding the sequence in two halves through the carried state
        equals one full pass (the decode-path invariant)."""
        cfg = _cfg(chunk=4)
        p = init_mamba(jax.random.key(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model)) * 0.5
        y_full, _ = mamba_mix(cfg, p, x)
        y1, st = mamba_mix(cfg, p, x[:, :9])
        y2, _ = mamba_mix(cfg, p, x[:, 9:], st)
        y_stream = jnp.concatenate([y1, y2], axis=1)
        np.testing.assert_allclose(
            np.asarray(y_stream), np.asarray(y_full), atol=1e-4
        )

    def test_reference_scan(self):
        """Chunked scan ≡ naive per-step recurrence."""
        cfg = _cfg(chunk=16, d=16, heads=2, ds=3)
        p = init_mamba(jax.random.key(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.key(1), (1, 8, cfg.d_model)) * 0.5
        y, _ = mamba_mix(cfg, p, x)
        # naive: run length-1 chunks step by step
        cfg1 = _cfg(chunk=1, d=16, heads=2, ds=3)
        st = None
        outs = []
        for t in range(8):
            yt, st = mamba_mix(cfg1, p, x[:, t : t + 1], st)
            outs.append(yt)
        np.testing.assert_allclose(
            np.asarray(jnp.concatenate(outs, 1)), np.asarray(y), atol=1e-4
        )


class TestMLSTM:
    @pytest.mark.parametrize("chunk", [1, 2, 5, 16])
    def test_chunking_invariance(self, chunk):
        cfg_ref = _cfg(chunk=16)
        cfg = _cfg(chunk=chunk)
        p = init_mlstm(jax.random.key(0), cfg_ref, jnp.float32)
        x = jax.random.normal(jax.random.key(1), (2, 16, cfg_ref.d_model)) * 0.5
        y_ref, _ = mlstm_mix(cfg_ref, p, x)
        y, _ = mlstm_mix(cfg, p, x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-4)

    def test_streaming_equals_batch(self):
        cfg = _cfg(chunk=4)
        p = init_mlstm(jax.random.key(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.key(1), (2, 12, cfg.d_model)) * 0.5
        y_full, _ = mlstm_mix(cfg, p, x)
        y1, st = mlstm_mix(cfg, p, x[:, :7])
        y2, _ = mlstm_mix(cfg, p, x[:, 7:], st)
        np.testing.assert_allclose(
            np.asarray(jnp.concatenate([y1, y2], 1)),
            np.asarray(y_full),
            atol=2e-4,
        )

    def test_forget_gate_decays_carry(self):
        """With strongly negative forget logits the memory resets; outputs
        must stay finite (stabiliser working)."""
        cfg = _cfg(chunk=4)
        p = init_mlstm(jax.random.key(0), cfg, jnp.float32)
        p = dict(p)
        p["f_gate"] = {"w": p["f_gate"]["w"], "b": jnp.full((cfg.n_heads,), -30.0)}
        x = jax.random.normal(jax.random.key(1), (1, 8, cfg.d_model))
        y, st = mlstm_mix(cfg, p, x)
        assert bool(jnp.all(jnp.isfinite(y)))
        assert bool(jnp.all(jnp.isfinite(st.c)))


class TestSLSTM:
    def test_streaming_equals_batch(self):
        cfg = _cfg(chunk=4, d=16)
        p = init_slstm(jax.random.key(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.key(1), (2, 10, cfg.d_model)) * 0.5
        y_full, _ = slstm_mix(cfg, p, x)
        y1, st = slstm_mix(cfg, p, x[:, :6])
        y2, _ = slstm_mix(cfg, p, x[:, 6:], st)
        np.testing.assert_allclose(
            np.asarray(jnp.concatenate([y1, y2], 1)),
            np.asarray(y_full),
            atol=1e-5,
        )

    def test_stability_long_run(self):
        cfg = _cfg(chunk=4, d=16)
        p = init_slstm(jax.random.key(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.key(1), (1, 200, cfg.d_model)) * 2.0
        y, st = slstm_mix(cfg, p, x)
        assert bool(jnp.all(jnp.isfinite(y)))
        assert bool(jnp.all(jnp.isfinite(st.m)))
