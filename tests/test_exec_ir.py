"""Execution IR (repro.exec): lowering, cursors, run-many serving.

Covers the tentpole contracts of the program-IR refactor:

* lowering is lossless (``exec_program().system == plan.system`` exactly)
  and resolves endpoints / step bindings / leadership;
* every backend's compiled artifact interprets the *same* shared
  ``ExecProgram`` (compile-once, no per-backend re-derivation);
* ``FlatTrace.compact`` (the core op-array export) honours deletions and
  smart-constructor identities;
* ``Cursor`` implements the active-occurrence semantics incrementally;
* ``Executable.run_many`` amortises one lowered program over a batch with
  correct results in input order, and the re-entry guard follows the
  backend's ``concurrent_batches()`` capability: the threaded backend
  serves overlapping batches from one Executable (each isolated by
  batch-unique endpoint tags), the others stay mutually exclusive — as
  does threaded itself when the caller shares a transport across runs.
"""

from __future__ import annotations

import threading

import pytest

from conftest import identity_step_fns

from repro import swirl
from repro.api import ConcurrentRunError
from repro.backends import available_backends
from repro.core.compile import StepMeta
from repro.core.flat import FlatTrace
from repro.core.parser import parse_trace
from repro.core.syntax import Exec, Recv, Send, actions
from repro.core.translate import genomes_1000
from repro.exec import Cursor, ExecOp, RecvOp, SendOp, lower_system, to_action

EDGES = {
    "preprocess": ["train_a", "train_b"],
    "train_a": ["evaluate"],
    "train_b": ["evaluate"],
    "evaluate": ["report"],
    "report": [],
}
MAPPING = {
    "preprocess": ("cpu0",),
    "train_a": ("gpu0",),
    "train_b": ("gpu1",),
    "evaluate": ("gpu0",),
    "report": ("cpu0",),
}


def quickstart_plan():
    return swirl.trace(EDGES, mapping=MAPPING).optimize()


def quickstart_steps():
    return {
        "preprocess": lambda inp: {"d^preprocess": list(range(10))},
        "train_a": lambda inp: {"d^train_a": sum(inp["d^preprocess"])},
        "train_b": lambda inp: {"d^train_b": max(inp["d^preprocess"])},
        "evaluate": lambda inp: {
            "d^evaluate": inp["d^train_a"] + inp["d^train_b"]
        },
        "report": lambda inp: {},
    }


def _genomes(n=2, m=2):
    inst = genomes_1000(n=n, m=m, a=1, b=1, c=1)
    fns = identity_step_fns(inst)
    init = {("l^d", d): f"raw:{d}" for d in inst.g("l^d")}
    return inst, fns, init


def _seeded_instance():
    """A workflow whose source step *consumes* per-instance initial data,
    so distinct ``run_many`` inputs must surface in distinct results."""
    from repro.core.graph import DistributedWorkflowInstance, make_workflow

    wf = make_workflow(
        ["ingest", "transform"],
        ["p_seed", "p_ingest"],
        [
            ("p_seed", "ingest"),
            ("ingest", "p_ingest"),
            ("p_ingest", "transform"),
        ],
    )
    inst = DistributedWorkflowInstance(
        workflow=wf,
        locations=frozenset({"l0", "l1"}),
        mapping={"ingest": ("l0",), "transform": ("l1",)},
        data=frozenset({"d_seed", "d_ingest"}),
        placement={"d_seed": "p_seed", "d_ingest": "p_ingest"},
        initial_data={"l0": frozenset({"d_seed"})},
    )
    return inst, identity_step_fns(inst)


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------


class TestLowering:
    def test_roundtrip_is_exact(self):
        for plan in (
            quickstart_plan(),
            swirl.trace(genomes_1000(n=3, m=2, a=2, b=2, c=2)).optimize(
                ("R1R2", "R3")
            ),
        ):
            program = lower_system(plan.system)
            assert program.system == plan.system

    def test_ops_follow_program_order(self):
        plan = quickstart_plan()
        program = plan.exec_program()
        for cfg in plan.system.configs:
            lp = program[cfg.location]
            assert [to_action(op) for op in lp.ops] == list(
                actions(cfg.trace)
            )

    def test_resolution(self):
        plan = quickstart_plan()
        program = plan.exec_program()
        for lp in program.programs:
            for op in lp.ops:
                if isinstance(op, (SendOp, RecvOp)):
                    assert op.endpoint == (op.src, op.dst, op.port)
                elif isinstance(op, ExecOp):
                    assert list(op.inputs) == sorted(op.inputs)
                    assert op.leader == (
                        lp.location == min(op.locations)
                    )
        assert program.placement() == plan.placement()
        # Every endpoint appears on both sides exactly once in channels().
        eps = program.channels()
        assert len(eps) == len(set(eps))

    def test_leader_unique_per_spatial_step(self):
        mapping = dict(MAPPING, evaluate=("gpu0", "gpu1"))
        plan = swirl.trace(EDGES, mapping=mapping).optimize()
        program = plan.exec_program()
        leaders = [
            lp.location
            for lp in program.programs
            for op in lp.exec_ops()
            if op.step == "evaluate" and op.leader
        ]
        assert leaders == ["gpu0"]

    def test_exec_program_cached_on_plan(self):
        plan = quickstart_plan()
        assert plan.exec_program() is plan.exec_program()

    def test_every_backend_shares_the_plan_program(self):
        plan = quickstart_plan()
        steps = quickstart_steps()
        for name in available_backends():
            exe = plan.lower(name).compile(steps)
            assert exe.program.program is plan.exec_program(), name

    def test_legacy_system_compile_is_coerced(self):
        from repro.backends import get_backend

        plan = quickstart_plan()
        metas = {
            name: StepMeta(fn=fn)
            for name, fn in quickstart_steps().items()
        }
        program = get_backend("inprocess").compile(plan.system, metas, {})
        result = program.run()
        assert result.payload("cpu0", "d^evaluate") == 54


# ---------------------------------------------------------------------------
# FlatTrace.compact — the core op-array export
# ---------------------------------------------------------------------------


class TestCompact:
    def test_compact_matches_rebuild(self):
        trace = parse_trace(
            "exec(a,{}->{x},{l}).(send(x->p,l,m) | recv(q,m,l)).0"
        )
        flat = FlatTrace.from_trace(trace)
        # Kill one action and compare against the tree reconstruction.
        flat.alive[1] = False
        compacted = flat.compact()
        assert compacted.rebuild() == flat.rebuild()
        assert all(compacted.alive)
        assert len(compacted.actions) == 2

    def test_compact_of_live_trace_is_lossless(self):
        _, w, *_ = (None, quickstart_plan().system)
        for cfg in w.configs:
            flat = FlatTrace.from_trace(cfg.trace)
            assert flat.compact().rebuild() == cfg.trace


# ---------------------------------------------------------------------------
# Cursor
# ---------------------------------------------------------------------------


class TestCursor:
    def _program(self, text: str):
        from repro.core.syntax import LocationConfig, WorkflowSystem

        trace = parse_trace(text)
        system = WorkflowSystem(
            (LocationConfig("l", frozenset(), trace),)
        )
        return lower_system(system)["l"]

    def test_sequence_gates_successors(self):
        lp = self._program("send(x->p,l,m).send(y->q,l,m).send(z->r,l,m)")
        cur = Cursor(lp)
        assert cur.enabled_ops() == [0]
        cur.complete(0)
        assert cur.enabled_ops() == [1]
        cur.complete(1)
        cur.complete(2)
        assert cur.finished()

    def test_par_exposes_all_branches(self):
        lp = self._program(
            "(send(x->p,l,m) | send(y->q,l,m)).send(z->r,l,m)"
        )
        cur = Cursor(lp)
        assert cur.enabled_ops() == [0, 1]
        cur.complete(1)
        assert cur.enabled_ops() == [0]
        cur.complete(0)
        assert cur.enabled_ops() == [2]
        cur.complete(2)
        assert cur.finished()

    def test_complete_requires_active(self):
        lp = self._program("send(x->p,l,m).send(y->q,l,m)")
        cur = Cursor(lp)
        with pytest.raises(ValueError, match="not active"):
            cur.complete(1)

    def test_done_flags_drive_remaining_system(self):
        plan = quickstart_plan()
        program = plan.exec_program()
        cursors = {
            lp.location: Cursor(lp) for lp in program.programs
        }
        # Nothing done: the remaining term is the whole plan.
        remaining = program.remaining_system(
            {l: c.done_flags() for l, c in cursors.items()}
        )
        assert remaining.canonical() == plan.system.canonical()
        # Everything done: the remaining term is terminated.
        for lp in program.programs:
            cur = cursors[lp.location]
            while not cur.finished():
                cur.complete(cur.enabled_ops()[0])
        remaining = program.remaining_system(
            {l: c.done_flags() for l, c in cursors.items()}
        )
        assert remaining.is_terminated()


# ---------------------------------------------------------------------------
# run_many — compile-once / run-many serving
# ---------------------------------------------------------------------------

SERVE_BACKENDS = [b for b in ("inprocess", "threaded", "jax")
                  if b in available_backends()]


class TestRunMany:
    @pytest.mark.parametrize("backend", SERVE_BACKENDS)
    def test_results_match_individual_runs(self, backend):
        inst, fns, init = _genomes()
        plan = swirl.trace(inst).optimize()
        exe = plan.lower(backend).compile(fns)
        inputs = [
            {k: f"inst{i}:{v}" for k, v in init.items()} for i in range(6)
        ]
        batch = exe.run_many(inputs, max_concurrent=3)
        assert len(batch) == 6
        for i, result in enumerate(batch):
            solo = (
                plan.lower(backend)
                .compile(fns)
                .run(initial_payloads=inputs[i])
            )
            assert result.data == solo.data, f"instance {i} diverged"

    def test_results_in_input_order_no_cross_instance_leaks(self):
        inst, fns = _seeded_instance()
        exe = swirl.trace(inst).optimize().lower("threaded").compile(fns)
        inputs = [
            {("l0", "d_seed"): f"inst{i}"} for i in range(8)
        ]
        batch = exe.run_many(inputs, max_concurrent=8)
        for i, result in enumerate(batch):
            # ingest(d_seed=inst{i}) flows through the shared transport to
            # l1 — the right instance's payload, nobody else's.
            got = result.payload("l1", "d_ingest")
            assert got == f"ingest(d_seed=inst{i})", got

    @pytest.mark.skipif(
        "multiprocess" not in available_backends(),
        reason="multiprocess backend unavailable",
    )
    def test_multiprocess_batches_serialise_safely(self):
        """run_many on the process backend: instances are serialised (each
        run owns the shared snapshot state and a full worker fleet) but
        results still come back per instance, in order."""
        inst, fns = _seeded_instance()
        plan = swirl.trace(inst).optimize()
        exe = plan.lower("multiprocess", timeout_s=60).compile(fns)
        inputs = [{("l0", "d_seed"): f"inst{i}"} for i in range(2)]
        batch = exe.run_many(inputs, max_concurrent=2)
        for i, result in enumerate(batch):
            assert result.payload("l1", "d_ingest") == (
                f"ingest(d_seed=inst{i})"
            )

    def test_empty_batch(self):
        plan = quickstart_plan()
        exe = plan.lower("threaded").compile(quickstart_steps())
        assert exe.run_many([]) == []

    def test_invalid_concurrency_rejected(self):
        plan = quickstart_plan()
        exe = plan.lower("threaded").compile(quickstart_steps())
        with pytest.raises(ValueError, match="max_concurrent"):
            exe.run_many([None], max_concurrent=0)

    def test_instance_failure_propagates(self):
        plan = quickstart_plan()
        steps = dict(quickstart_steps())

        def boom(inp):
            raise RuntimeError("boom")

        steps["evaluate"] = boom
        exe = plan.lower("threaded", timeout_s=5).compile(steps)
        with pytest.raises(RuntimeError):
            exe.run_many([None, None], max_concurrent=2)
        # The guard was released — the executable is reusable.
        good = plan.lower("threaded").compile(quickstart_steps())
        assert good.run().payload("cpu0", "d^evaluate") == 54


class TestRunManyGuard:
    def _slow_exe(self, started, release, lowered):
        steps = dict(quickstart_steps())

        def slow_preprocess(inp):
            started.set()
            assert release.wait(20)
            return {"d^preprocess": list(range(10))}

        steps["preprocess"] = slow_preprocess
        return lowered.compile(steps)

    def test_threaded_serves_concurrent_batches(self):
        """One threaded Executable overlaps whole batches (the serving
        hot path): results stay isolated and the guard never trips."""
        started, release = threading.Event(), threading.Event()
        plan = quickstart_plan()
        exe = self._slow_exe(started, release, plan.lower("threaded"))
        assert exe.concurrent_runs
        results = {}

        def batch():
            results["batch"] = exe.run_many([None, None], max_concurrent=2)

        t = threading.Thread(target=batch, daemon=True)
        t.start()
        assert started.wait(10)
        try:
            assert exe.active_runs == 1
            # Overlapping work on the SAME executable is now served, not
            # rejected: a second batch and a single run both complete
            # while the first batch is still blocked in its step.
            release.set()
            overlap_batch = exe.run_many([None])
            overlap_run = exe.run()
        finally:
            release.set()
            t.join(30)
        assert len(results["batch"]) == 2
        for r in results["batch"]:
            assert r.payload("cpu0", "d^evaluate") == 54
        assert overlap_batch[0].payload("cpu0", "d^evaluate") == 54
        assert overlap_run.payload("cpu0", "d^evaluate") == 54
        assert exe.active_runs == 0

    def test_threaded_overlap_results_isolated(self):
        """Truly simultaneous batches on one Executable never cross
        wires: each batch sees exactly its own per-instance inputs."""
        inst, fns = _seeded_instance()
        exe = swirl.trace(inst).optimize().lower("threaded").compile(fns)
        n_batches, per_batch = 4, 5
        out: dict[int, list] = {}
        errors: list[Exception] = []
        gate = threading.Barrier(n_batches)

        def batch(b):
            inputs = [
                {("l0", "d_seed"): f"b{b}i{i}"} for i in range(per_batch)
            ]
            gate.wait()
            try:
                out[b] = exe.run_many(inputs, max_concurrent=per_batch)
            except Exception as e:  # surface in the main thread
                errors.append(e)

        threads = [
            threading.Thread(target=batch, args=(b,))
            for b in range(n_batches)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join(60)
        assert not errors
        for b in range(n_batches):
            got = [r.payload("l1", "d_ingest") for r in out[b]]
            assert got == [
                f"ingest(d_seed=b{b}i{i})" for i in range(per_batch)
            ]

    def test_exclusive_backend_rejects_overlap(self):
        """Backends without the concurrent-batches capability keep the
        old mutual-exclusion contract."""
        started, release = threading.Event(), threading.Event()
        plan = quickstart_plan()
        exe = self._slow_exe(started, release, plan.lower("inprocess"))
        assert not exe.concurrent_runs
        results = {}

        def run():
            results["run"] = exe.run()

        t = threading.Thread(target=run, daemon=True)
        t.start()
        assert started.wait(10)
        try:
            with pytest.raises(ConcurrentRunError):
                exe.run()
            with pytest.raises(ConcurrentRunError):
                exe.run_many([None])
        finally:
            release.set()
            t.join(30)
        assert results["run"].payload("cpu0", "d^evaluate") == 54
        # After the run drains, the guard is free again.
        assert exe.run().payload("cpu0", "d^evaluate") == 54

    def test_shared_transport_disables_concurrency(self):
        """A caller-shared transport/registry makes untagged endpoints
        collide across runs, so the capability switches off."""
        from repro.workflow.channels import ChannelRegistry

        started, release = threading.Event(), threading.Event()
        plan = quickstart_plan()
        exe = self._slow_exe(
            started,
            release,
            plan.lower("threaded", channels=ChannelRegistry()),
        )
        assert not exe.concurrent_runs

        def run():
            exe.run()

        t = threading.Thread(target=run, daemon=True)
        t.start()
        assert started.wait(10)
        try:
            with pytest.raises(ConcurrentRunError):
                exe.run()
        finally:
            release.set()
            t.join(30)

    def test_internal_parallelism_not_rejected(self):
        """max_concurrent > 1 must not trip the re-entry guard."""
        plan = quickstart_plan()
        exe = plan.lower("threaded").compile(quickstart_steps())
        batch = exe.run_many([None] * 6, max_concurrent=6)
        assert [r.payload("cpu0", "d^evaluate") for r in batch] == [54] * 6
