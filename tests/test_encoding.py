"""Encoding ``⟦·⟧`` — Defs. 10-12, checked against the paper's Example 2
and the Appendix-B 1000 Genomes system."""

from repro.core import encode, building_block
from repro.core.parser import parse_trace
from repro.core.syntax import Exec, Par, Recv, Send, congruent, actions
from repro.core.translate import genomes_1000

from test_graph import fig1_instance


class TestExample2:
    """The paper's Example 2: the Fig. 1 instance encodes exactly to W."""

    def test_driver_trace(self):
        w = encode(fig1_instance())
        want = parse_trace(
            "exec(s1,{}->{d1,d2},{ld})."
            "(send(d1->p1,ld,l1) | send(d2->p2,ld,l2) | send(d2->p2,ld,l3))"
        )
        assert congruent(w["ld"].trace, want)

    def test_l1_trace(self):
        w = encode(fig1_instance())
        want = parse_trace("recv(p1,ld,l1).exec(s2,{d1}->{},{l1})")
        assert congruent(w["l1"].trace, want)

    def test_spatial_constraint_traces(self):
        w = encode(fig1_instance())
        for loc in ("l2", "l3"):
            want = parse_trace(
                f"recv(p2,ld,{loc}).exec(s3,{{d2}}->{{}},{{l2,l3}})"
            )
            assert congruent(w[loc].trace, want)

    def test_initial_data_empty(self):
        w = encode(fig1_instance())
        for cfg in w.configs:
            assert cfg.data == frozenset()


class TestBuildingBlock:
    def test_source_step_has_nil_recv(self):
        # B_ld(s1) = 0.exec(...).sends — i.e. no receive prefix
        inst = fig1_instance()
        b = building_block(inst, "s1", "ld")
        acts = list(actions(b))
        assert isinstance(acts[0], Exec)
        assert all(isinstance(a, Send) for a in acts[1:])

    def test_sink_step_has_nil_send(self):
        inst = fig1_instance()
        b = building_block(inst, "s2", "l1")
        acts = list(actions(b))
        assert isinstance(acts[0], Recv)
        assert isinstance(acts[-1], Exec)

    def test_recv_per_producer_location(self):
        # s3 on l2 receives d2 once (from ld, the only producer location)
        inst = fig1_instance()
        b = building_block(inst, "s3", "l2")
        recvs = [a for a in actions(b) if isinstance(a, Recv)]
        assert recvs == [Recv("p2", "ld", "l2")]

    def test_send_per_consumer_location(self):
        # s1 sends d2 to both locations of s3 over the same port p2
        inst = fig1_instance()
        b = building_block(inst, "s1", "ld")
        sends = [a for a in actions(b) if isinstance(a, Send)]
        assert Send("d2", "p2", "ld", "l2") in sends
        assert Send("d2", "p2", "ld", "l3") in sends

    def test_unmapped_location_rejected(self):
        inst = fig1_instance()
        try:
            building_block(inst, "s1", "l1")
            assert False
        except ValueError:
            pass


class TestGenomes1000:
    """Appendix B structure: driver fan-out, IM broadcast shape."""

    def test_location_count(self):
        inst = genomes_1000(n=4, m=3, a=2, b=2, c=2)
        w = encode(inst)
        # l^d, l^IM, l^SF + 2 I + 2 MO + 2 F = 9
        assert len(w.configs) == 9

    def test_driver_sends(self):
        inst = genomes_1000(n=4, m=3, a=2, b=2, c=2)
        w = encode(inst)
        sends = [
            a for a in actions(w["l^d"].trace) if isinstance(a, Send)
        ]
        # n individuals inputs + 1 sifting + m·(MO+F) population files
        assert len(sends) == 4 + 1 + 3 * 2

    def test_im_broadcast_before_optimisation(self):
        # e^IM sends d^IM once per consuming STEP (m MO steps + m F steps)
        inst = genomes_1000(n=4, m=3, a=2, b=2, c=2)
        w = encode(inst)
        sends = [
            a
            for a in actions(w["l^IM"].trace)
            if isinstance(a, Send) and a.data == "d^IM"
        ]
        assert len(sends) == 3 + 3  # one per consumer step (m=3 MO, m=3 F)

    def test_driver_initial_data(self):
        inst = genomes_1000(n=4, m=3)
        w = encode(inst)
        assert "d0_1" in w["l^d"].data
        assert "d0_SF" in w["l^d"].data


class TestDeterminism:
    def test_encode_is_deterministic(self):
        a = encode(genomes_1000())
        b = encode(genomes_1000())
        assert a == b
