"""Staged-compilation API: trace → Plan → Lowered → Executable.

Covers the full round trip on the quickstart DAG across all three in-tree
backends (identical outputs), bisimilarity preservation of ``Plan.optimize``,
the backend registry, checkpoint/restore, and the legacy deprecation shims.
"""

from __future__ import annotations

import warnings

import pytest

from repro import swirl
from repro.backends import (
    UnknownBackendError,
    available_backends,
    get_backend,
    register_backend,
)
from repro.backends.base import (
    Backend,
    BackendCapabilityError,
    ExecutionResult,
)
from repro.core import weak_barbed_bisimilar
from repro.core.compile import StepMeta
from repro.core.translate import DagTranslator

BACKENDS = ("inprocess", "threaded", "jax")

EDGES = {
    "preprocess": ["train_a", "train_b"],
    "train_a": ["evaluate"],
    "train_b": ["evaluate"],
    "evaluate": ["report"],
    "report": [],
}
MAPPING = {
    "preprocess": ("cpu0",),
    "train_a": ("gpu0",),
    "train_b": ("gpu1",),
    "evaluate": ("gpu0",),
    "report": ("cpu0",),
}


def quickstart_steps():
    return {
        "preprocess": lambda inp: {"d^preprocess": list(range(10))},
        "train_a": lambda inp: {"d^train_a": sum(inp["d^preprocess"])},
        "train_b": lambda inp: {"d^train_b": max(inp["d^preprocess"])},
        "evaluate": lambda inp: {
            "d^evaluate": inp["d^train_a"] + inp["d^train_b"]
        },
        "report": lambda inp: {},
    }


@pytest.fixture
def plan():
    return swirl.trace(EDGES, mapping=MAPPING).optimize()


# ---------------------------------------------------------------------------
# trace
# ---------------------------------------------------------------------------


class TestTrace:
    def test_from_edges_requires_mapping(self):
        with pytest.raises(TypeError, match="mapping"):
            swirl.trace(EDGES)

    def test_from_translator(self):
        p = swirl.trace(DagTranslator(edges=EDGES, mapping=MAPPING))
        assert p.instance is not None
        assert set(p.steps()) == set(EDGES)

    def test_from_instance(self):
        inst = DagTranslator(edges=EDGES, mapping=MAPPING).instance()
        p = swirl.trace(inst)
        assert p.system.comm_count() > 0

    def test_from_swirl_source_roundtrip(self, plan):
        from repro.core.parser import dumps

        p2 = swirl.trace(dumps(plan.system))
        assert p2.system.canonical() == plan.system.canonical()

    def test_from_swirl_file(self, plan, tmp_path):
        from repro.core.parser import dumps

        f = tmp_path / "plan.swirl"
        f.write_text(dumps(plan.system))
        p2 = swirl.trace(str(f))
        assert p2.system.canonical() == plan.system.canonical()

    def test_rejects_garbage(self):
        with pytest.raises(TypeError):
            swirl.trace(42)

    def test_missing_swirl_file_is_an_error(self, tmp_path):
        with pytest.raises(FileNotFoundError):
            swirl.trace(str(tmp_path / "nope.swirl"))

    def test_pathlike_is_always_a_file(self, tmp_path):
        with pytest.raises(FileNotFoundError):
            swirl.trace(tmp_path / "nope.txt")


# ---------------------------------------------------------------------------
# Plan.optimize / certify / explain
# ---------------------------------------------------------------------------


class TestPlan:
    def test_optimize_removes_local_comms(self):
        raw = swirl.trace(EDGES, mapping=MAPPING)
        opt = raw.optimize()
        assert opt.system.comm_count() < raw.system.comm_count()
        assert opt.stats.removed > 0
        assert opt.rewrites[0].rule == "R1R2"

    def test_optimize_preserves_weak_barbed_bisimilarity(self):
        raw = swirl.trace(EDGES, mapping=MAPPING)
        opt = raw.optimize()
        assert weak_barbed_bisimilar(raw.system, opt.system)

    def test_certify_attaches_certificate(self):
        plan = swirl.trace(EDGES, mapping=MAPPING).optimize(certify=True)
        cert = plan.certificate
        assert cert is not None and cert.equivalent
        assert cert.states_optimized <= cert.states_original

    def test_unknown_rule_rejected(self):
        with pytest.raises(ValueError, match="unknown rewrite rule"):
            swirl.trace(EDGES, mapping=MAPPING).optimize(rules=("R9",))

    def test_explain_mentions_rewrites_and_placement(self, plan):
        text = plan.explain()
        assert "R1R2" in text
        assert "train_a" in text and "gpu0" in text
        assert "exec" in text  # the pretty-printed traces

    def test_placement_typo_rejected(self, plan):
        with pytest.raises(ValueError, match="unknown steps"):
            plan.lower("inprocess", placement={"evalute": ("gpu1",)})

    def test_placement_override_relowers(self, plan):
        moved = plan.lower(
            "inprocess", placement={"evaluate": ("gpu1",)}
        )
        assert moved.plan.placement()["evaluate"] == ("gpu1",)
        result = moved.compile(quickstart_steps()).run()
        assert result.payload("gpu1", "d^evaluate") == 54


# ---------------------------------------------------------------------------
# The full round trip, identical across backends
# ---------------------------------------------------------------------------


class TestRoundTrip:
    @pytest.mark.parametrize("backend", BACKENDS)
    def test_run(self, plan, backend):
        result = plan.lower(backend).compile(quickstart_steps()).run()
        assert result.backend == backend
        assert result.payload("cpu0", "d^evaluate") == 54

    def test_all_backends_identical(self, plan):
        results = {
            b: plan.lower(b).compile(quickstart_steps()).run()
            for b in BACKENDS
        }
        datas = [r.data for r in results.values()]
        assert datas[0] == datas[1] == datas[2]

    def test_pipeline_emits_no_deprecation_warnings(self, plan):
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            plan.lower("threaded").compile(quickstart_steps()).run()

    def test_run_async(self, plan):
        fut = plan.lower("inprocess").compile(quickstart_steps()).run_async()
        assert fut.result(timeout=60).payload("cpu0", "d^evaluate") == 54

    def test_missing_step_fn_rejected(self, plan):
        steps = quickstart_steps()
        del steps["evaluate"]
        with pytest.raises(KeyError, match="evaluate"):
            plan.lower("inprocess").compile(steps)

    def test_step_meta_accepted(self, plan):
        steps = {
            name: StepMeta(fn=fn, expected_seconds=0.01)
            for name, fn in quickstart_steps().items()
        }
        result = plan.lower("threaded").compile(steps).run()
        assert result.payload("cpu0", "d^evaluate") == 54

    def test_unknown_lowering_option_rejected(self, plan):
        with pytest.raises(TypeError, match="unknown options"):
            plan.lower("jax", warp_speed=True)

    def test_channels_and_channel_options_conflict(self, plan):
        from repro.workflow.channels import ChannelRegistry

        exe = plan.lower(
            "threaded", channels=ChannelRegistry(), seed=7
        ).compile(quickstart_steps())
        with pytest.raises(TypeError, match="not both"):
            exe.run()


# ---------------------------------------------------------------------------
# Concurrent re-entry guard
# ---------------------------------------------------------------------------


class TestConcurrentRun:
    @staticmethod
    def _slow_steps(started, release):
        steps = quickstart_steps()

        def slow_preprocess(inp):
            started.set()
            release.wait(10)
            return {"d^preprocess": list(range(10))}

        steps["preprocess"] = slow_preprocess
        return steps

    def test_overlapping_run_raises(self, plan):
        import threading

        started, release = threading.Event(), threading.Event()
        exe = plan.lower("inprocess").compile(
            self._slow_steps(started, release)
        )
        fut = exe.run_async()
        assert started.wait(10), "first run never started"
        try:
            with pytest.raises(swirl.ConcurrentRunError, match="already"):
                exe.run()
            with pytest.raises(swirl.ConcurrentRunError):
                exe.run_async().result(timeout=10)
        finally:
            release.set()
        assert fut.result(timeout=30).payload("cpu0", "d^evaluate") == 54
        # The guard clears once the in-flight run finishes.
        assert exe.run().payload("cpu0", "d^evaluate") == 54

    def test_guard_clears_after_failure(self, plan):
        steps = quickstart_steps()
        steps["evaluate"] = lambda inp: (_ for _ in ()).throw(
            RuntimeError("boom")
        )
        exe = plan.lower("inprocess").compile(steps)
        with pytest.raises(Exception, match="failed"):
            exe.run()
        exe2 = plan.lower("inprocess").compile(quickstart_steps())
        assert exe.active_runs == 0
        assert exe2.run().payload("cpu0", "d^evaluate") == 54

    def test_distinct_executables_may_overlap(self, plan):
        import threading

        started, release = threading.Event(), threading.Event()
        lowered = plan.lower("inprocess")
        exe1 = lowered.compile(self._slow_steps(started, release))
        exe2 = lowered.compile(quickstart_steps())
        fut = exe1.run_async()
        assert started.wait(10)
        try:
            assert exe2.run().payload("cpu0", "d^evaluate") == 54
        finally:
            release.set()
        assert fut.result(timeout=30).payload("cpu0", "d^evaluate") == 54


# ---------------------------------------------------------------------------
# Checkpoint / restore — every backend advertising the capability
# ---------------------------------------------------------------------------

CHECKPOINT_BACKENDS = [
    name
    for name in available_backends()
    if "checkpoint" in get_backend(name).capabilities
]


class TestCheckpoint:
    def test_inprocess_advertises_checkpoint(self):
        assert "inprocess" in CHECKPOINT_BACKENDS

    def test_checkpoint_restore_roundtrip(self, plan):
        exe = plan.lower("inprocess").compile(quickstart_steps())
        first = exe.run()
        ckpt = exe.checkpoint()
        assert "preprocess" in ckpt.completed_execs

        exe2 = plan.lower("inprocess").compile(quickstart_steps())
        result = exe2.restore(ckpt).run()
        assert result.data == first.data

    @pytest.mark.parametrize("backend", CHECKPOINT_BACKENDS)
    def test_capability_roundtrip_after_run(self, plan, backend):
        """Post-run snapshot restores to the same final data everywhere."""
        exe = plan.lower(backend).compile(quickstart_steps())
        done = exe.run()
        ckpt = exe.checkpoint()
        restored = (
            plan.lower(backend)
            .compile(quickstart_steps())
            .restore(ckpt)
            .run()
        )
        assert restored.data == done.data
        assert restored.backend == backend

    @pytest.mark.parametrize("backend", CHECKPOINT_BACKENDS)
    def test_capability_roundtrip_pristine(self, plan, backend):
        """A pre-run snapshot restores to a full from-scratch run."""
        exe = plan.lower(backend).compile(quickstart_steps())
        pristine = exe.checkpoint()
        direct = plan.lower(backend).compile(quickstart_steps()).run()
        restored = (
            plan.lower(backend)
            .compile(quickstart_steps())
            .restore(pristine)
            .run()
        )
        assert restored.data == direct.data

    def test_threaded_backend_lacks_checkpoint(self, plan):
        exe = plan.lower("threaded").compile(quickstart_steps())
        with pytest.raises(BackendCapabilityError):
            exe.checkpoint()


# ---------------------------------------------------------------------------
# Backend registry
# ---------------------------------------------------------------------------


class TestRegistry:
    def test_builtins_available(self):
        names = available_backends()
        for b in BACKENDS:
            assert b in names

    def test_unknown_backend(self):
        with pytest.raises(UnknownBackendError):
            get_backend("nonexistent-backend")

    def test_register_and_use_custom_backend(self, plan):
        calls = {}

        class EchoBackend(Backend):
            name = "echo"

            def compile(self, system, steps, options):
                calls["compiled"] = True
                return get_backend("inprocess").compile(
                    system, steps, options
                )

        register_backend("echo-test", lambda: EchoBackend(), overwrite=True)
        try:
            result = (
                plan.lower("echo-test").compile(quickstart_steps()).run()
            )
            assert calls["compiled"]
            assert result.payload("cpu0", "d^evaluate") == 54
        finally:
            # keep the registry clean for other tests
            from repro import backends as _b

            _b._REGISTRY.pop("echo-test", None)

    def test_duplicate_registration_rejected(self):
        with pytest.raises(ValueError):
            register_backend("inprocess", lambda: None)


# ---------------------------------------------------------------------------
# Legacy deprecation shims
# ---------------------------------------------------------------------------


class TestDeprecationShims:
    def test_translate_warns_but_works(self, plan):
        with pytest.warns(DeprecationWarning, match="swirl.trace"):
            w = DagTranslator(edges=EDGES, mapping=MAPPING).translate()
        assert w.canonical() == swirl.trace(
            EDGES, mapping=MAPPING
        ).system.canonical()

    def test_optimize_warns_and_matches_plan(self, plan):
        from repro.core import optimize

        w = swirl.trace(EDGES, mapping=MAPPING).system
        with pytest.warns(DeprecationWarning, match="optimize"):
            o, stats = optimize(w)
        assert o.canonical() == plan.system.canonical()
        assert stats.removed == plan.stats.removed

    def test_compile_bundles_warns(self, plan):
        from repro.core.compile import compile_bundles

        with pytest.warns(DeprecationWarning, match="lower"):
            bundles = compile_bundles(plan.system, quickstart_steps())
        assert set(bundles) == set(plan.system.locations())

    def test_runtime_warns_and_matches_staged_result(self, plan):
        from repro.workflow import Runtime

        with pytest.warns(DeprecationWarning, match="inprocess"):
            rt = Runtime(plan.system, quickstart_steps())
        rt.run()
        staged = plan.lower("inprocess").compile(quickstart_steps()).run()
        for loc in plan.system.locations():
            assert rt.location_data(loc) == staged.location_data(loc)

    def test_threaded_runtime_warns(self, plan):
        from repro.core.compile import build_bundles
        from repro.workflow import ThreadedRuntime

        bundles = build_bundles(plan.system, quickstart_steps())
        with pytest.warns(DeprecationWarning, match="threaded"):
            rt = ThreadedRuntime(bundles)
        data = rt.run()
        assert data["cpu0"]["d^evaluate"] == 54


# ---------------------------------------------------------------------------
# Plan.fingerprint — the content address of a compiled plan
# ---------------------------------------------------------------------------


class TestFingerprint:
    def test_shape(self, plan):
        fp = plan.fingerprint()
        assert isinstance(fp, str) and len(fp) == 64
        int(fp, 16)  # hex digest

    def test_equal_plans_equal_fingerprints(self):
        """Two independently built but equal plans share a fingerprint —
        the contract the serving cache's content addressing relies on."""
        a = swirl.trace(EDGES, mapping=MAPPING).optimize()
        b = swirl.trace(
            dict(EDGES), mapping={s: tuple(ls) for s, ls in MAPPING.items()}
        ).optimize()
        assert a is not b
        assert a.fingerprint() == b.fingerprint()

    def test_stable_across_calls(self, plan):
        assert plan.fingerprint() == plan.fingerprint()

    def test_rules_change_fingerprint(self):
        traced = swirl.trace(EDGES, mapping=MAPPING)
        assert (
            traced.fingerprint()
            != traced.optimize().fingerprint()
        )

    def test_placement_change_fingerprint(self):
        moved = dict(MAPPING, evaluate=("gpu1",))
        a = swirl.trace(EDGES, mapping=MAPPING).optimize()
        b = swirl.trace(EDGES, mapping=moved).optimize()
        assert a.fingerprint() != b.fingerprint()

    def test_workflow_change_fingerprint(self):
        edges = dict(EDGES, report=["report2"], report2=[])
        mapping = dict(MAPPING, report2=("cpu0",))
        a = swirl.trace(EDGES, mapping=MAPPING).optimize()
        b = swirl.trace(edges, mapping=mapping).optimize()
        assert a.fingerprint() != b.fingerprint()


# ---------------------------------------------------------------------------
# Compile-cache coherence — clear_compile_cache vs live plans
# ---------------------------------------------------------------------------


class TestCompileCacheCoherence:
    def test_clear_invalidates_live_plan_exec_program(self, plan):
        """Regression: clear_compile_cache() used to leave already-derived
        ``Plan.exec_program()`` memos live, so a 'cleared' process kept
        serving stale lowered programs."""
        before = plan.exec_program()
        assert plan.exec_program() is before  # memoised
        swirl.clear_compile_cache()
        after = plan.exec_program()
        assert after is not before
        assert after.system == before.system  # same content, fresh derive
        assert plan.exec_program() is after  # re-memoised

    def test_stats_counters(self, plan):
        swirl.clear_compile_cache()
        base = swirl.compile_cache_stats()
        plan.schedule()  # derives via the module-level cache
        s1 = swirl.compile_cache_stats()
        assert s1["misses"] == base["misses"] + 1
        plan.schedule()
        s2 = swirl.compile_cache_stats()
        assert s2["hits"] >= s1["hits"]
        assert s2["entries"] >= 1
        swirl.clear_compile_cache()
        s3 = swirl.compile_cache_stats()
        assert s3["entries"] == 0
        assert s3["clears"] == s2["clears"] + 1
