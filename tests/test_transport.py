"""Transport conformance suite — run against every registered transport.

One parametrised test class exercises the :class:`repro.workflow.Transport`
contract (per-endpoint FIFO ordering, exactly-once effect under lossy wires
and lost acks, close-while-blocked raising ``ChannelClosed``, no
cross-endpoint leakage).  The parametrisation iterates the transport
registry, so a future transport gets the whole suite for free by calling
``register_transport`` and implementing ``Transport.conformance``.
"""

from __future__ import annotations

import gc
import glob
import multiprocessing
import os
import pickle
import sys
import threading
import time

import numpy as np
import pytest

from repro.workflow.transport import (
    TRANSPORTS,
    ChannelClosed,
    HybridTransport,
    InMemoryTransport,
    SharedMemoryTransport,
    SocketTransport,
    Transport,
    get_transport,
    register_transport,
    shm_namespace,
    socket_addresses,
)

LOCATIONS = ("alpha", "beta")
EP = ("alpha", "beta", "port0")


@pytest.fixture(params=sorted(TRANSPORTS))
def make(request, tmp_path):
    """Factory building (and tracking for teardown) conformance instances."""
    built: list[Transport] = []

    def factory(locations=LOCATIONS, **faults) -> Transport:
        t = TRANSPORTS[request.param].conformance(
            str(tmp_path / f"t{len(built)}"), locations, **faults
        )
        built.append(t)
        return t

    yield factory
    for t in built:
        t.close()


class TestTransportConformance:
    def test_per_endpoint_fifo_ordering(self, make):
        t = make()
        for i in range(64):
            t.send(EP, f"d{i}", i)
        got = [t.recv(EP, timeout=10.0).payload for _ in range(64)]
        assert got == list(range(64))

    def test_no_cross_endpoint_leakage(self, make):
        t = make()
        eps = [
            ("alpha", "beta", "p0"),
            ("alpha", "beta", "p1"),
            ("beta", "alpha", "p0"),
        ]
        for i in range(8):
            for j, ep in enumerate(eps):
                t.send(ep, f"d{j}", (j, i))
        for j, ep in enumerate(eps):
            got = [t.recv(ep, timeout=10.0).payload for _ in range(8)]
            assert got == [(j, i) for i in range(8)], f"leak into {ep}"

    def test_lossy_wire_delivers_exactly_once_in_order(self, make):
        """At-least-once resend on timeout + idempotent receive."""
        t = make(loss=0.5, seed=7)
        for i in range(32):
            t.send(EP, f"d{i}", i)
        got = [t.recv(EP, timeout=10.0).payload for _ in range(32)]
        assert got == list(range(32))
        with pytest.raises(TimeoutError):
            t.recv(EP, timeout=0.05)  # no duplicate ever surfaces

    def test_lost_acks_do_not_duplicate(self, make):
        """A swallowed ack forces a resend; the receive side deduplicates."""
        t = make(ack_loss=0.5, seed=11)
        for i in range(32):
            t.send(EP, f"d{i}", i)
        got = [t.recv(EP, timeout=10.0).payload for _ in range(32)]
        assert got == list(range(32))
        with pytest.raises(TimeoutError):
            t.recv(EP, timeout=0.05)

    def test_recv_timeout_raises_timeout_error(self, make):
        t = make()
        with pytest.raises(TimeoutError):
            t.recv(EP, timeout=0.05)

    def test_close_while_blocked_raises_channel_closed(self, make):
        t = make()
        caught: list[BaseException] = []
        blocked = threading.Event()

        def receiver():
            blocked.set()
            try:
                t.recv(EP, timeout=30.0)
            except ChannelClosed as e:
                caught.append(e)

        th = threading.Thread(target=receiver, daemon=True)
        th.start()
        assert blocked.wait(5.0)
        time.sleep(0.1)  # let the receiver actually block
        t.close()
        th.join(5.0)
        assert not th.is_alive(), "close() did not unblock the receiver"
        assert caught and isinstance(caught[0], ChannelClosed)

    def test_send_after_close_raises_channel_closed(self, make):
        t = make()
        t.close()
        with pytest.raises(ChannelClosed):
            t.send(EP, "d", 1)

    def test_pending_messages_drain_before_closed_raises(self, make):
        t = make()
        # send() blocks until the message is delivered/acked, so all three
        # are already in the inbox when close() lands.
        for i in range(3):
            t.send(EP, f"d{i}", i)
        t.close()
        got = [t.recv(EP, timeout=10.0).payload for _ in range(3)]
        assert got == [0, 1, 2]
        with pytest.raises(ChannelClosed):
            t.recv(EP, timeout=5.0)

    def test_close_is_idempotent(self, make):
        t = make()
        t.close()
        t.close()

    def test_concurrent_senders_on_distinct_endpoints(self, make):
        t = make()
        eps = [("alpha", "beta", f"p{i}") for i in range(4)]
        errs: list[BaseException] = []

        def sender(ep):
            try:
                for i in range(16):
                    t.send(ep, f"d{i}", i)
            except BaseException as e:  # noqa: BLE001
                errs.append(e)

        threads = [
            threading.Thread(target=sender, args=(ep,), daemon=True)
            for ep in eps
        ]
        for th in threads:
            th.start()
        for th in threads:
            th.join(30.0)
        assert not errs
        for ep in eps:
            got = [t.recv(ep, timeout=10.0).payload for _ in range(16)]
            assert got == list(range(16))


# ---------------------------------------------------------------------------
# Registry + construction specifics (not part of the per-transport contract)
# ---------------------------------------------------------------------------


class TestRegistry:
    def test_builtins_registered(self):
        assert get_transport("memory") is InMemoryTransport
        assert get_transport("socket") is SocketTransport

    def test_unknown_name(self):
        with pytest.raises(KeyError, match="unknown transport"):
            get_transport("carrier-pigeon")

    def test_duplicate_registration_rejected(self):
        with pytest.raises(ValueError, match="already registered"):
            register_transport("memory", InMemoryTransport)

    def test_crosses_processes_flags(self):
        assert not InMemoryTransport.crosses_processes
        assert SocketTransport.crosses_processes


class TestSocketSpecifics:
    def test_addresses_are_per_location_and_stable(self, tmp_path):
        a = socket_addresses(["x", "y", "z"], base_dir=tmp_path)
        b = socket_addresses(["z", "y", "x"], base_dir=tmp_path)
        assert a == b
        assert len(set(a.values())) == 3

    def test_serve_requires_address(self, tmp_path):
        addrs = socket_addresses(["x"], base_dir=tmp_path)
        with pytest.raises(KeyError, match="serve locations"):
            SocketTransport(addrs, serve=("ghost",))

    def test_resend_stats_recorded_under_loss(self, tmp_path):
        t = SocketTransport.conformance(
            str(tmp_path), LOCATIONS, loss=0.5, seed=3
        )
        try:
            for i in range(16):
                t.send(EP, f"d{i}", i)
            for _ in range(16):
                t.recv(EP, timeout=10.0)
            stats = t.stats()
            assert stats["dropped"] > 0
            assert stats["resends"] >= stats["dropped"]
            assert stats["delivered"] == 16
        finally:
            t.close()

    def test_unreachable_destination_raises(self, tmp_path):
        addrs = socket_addresses(LOCATIONS, base_dir=tmp_path)
        t = SocketTransport(addrs, serve=("alpha",), connect_timeout=0.3)
        try:
            with pytest.raises(ChannelClosed, match="cannot connect"):
                t.send(("alpha", "beta", "p"), "d", 1)
        finally:
            t.close()


class TestHybrid:
    """The co-residency composite used by multi-location worker processes."""

    @pytest.fixture
    def hybrid(self, tmp_path):
        remote = SocketTransport.conformance(
            str(tmp_path), ("alpha", "beta", "gamma")
        )
        t = HybridTransport(remote, ("alpha", "beta"))
        yield t
        t.close()

    def test_local_endpoints_never_touch_the_wire(self, hybrid):
        hybrid.send(("alpha", "beta", "p"), "d", 42)
        assert hybrid.recv(("alpha", "beta", "p"), timeout=5.0).payload == 42
        assert hybrid.stats()["remote"]["sent"] == 0
        assert hybrid.stats()["local"]["sent"] == 1

    def test_cross_endpoints_use_the_remote_wire(self, hybrid):
        hybrid.send(("alpha", "gamma", "p"), "d", 7)
        assert (
            hybrid.recv(("alpha", "gamma", "p"), timeout=5.0).payload == 7
        )
        assert hybrid.stats()["remote"]["sent"] == 1

    def test_close_closes_both_sides(self, hybrid):
        hybrid.close()
        with pytest.raises(ChannelClosed):
            hybrid.send(("alpha", "beta", "p"), "d", 1)
        with pytest.raises(ChannelClosed):
            hybrid.send(("alpha", "gamma", "p"), "d", 1)


# ---------------------------------------------------------------------------
# Batched sends — send_many / scatter share the per-message contract
# ---------------------------------------------------------------------------


class TestBatchedSends:
    def test_send_many_preserves_fifo(self, make):
        t = make()
        t.send_many(EP, [(f"d{i}", i) for i in range(48)])
        got = [t.recv(EP, timeout=10.0).payload for _ in range(48)]
        assert got == list(range(48))

    def test_send_many_empty_and_single(self, make):
        t = make()
        t.send_many(EP, [])
        t.send_many(EP, [("only", "x")])
        assert t.recv(EP, timeout=10.0).payload == "x"
        with pytest.raises(TimeoutError):
            t.recv(EP, timeout=0.05)

    def test_send_many_lossy_wire_exactly_once(self, make):
        """Dropped batch frames resend; the delivered prefix is skipped."""
        t = make(loss=0.5, seed=3)
        for base in range(0, 32, 8):
            t.send_many(EP, [(f"d{i}", base + i) for i in range(8)])
        got = [t.recv(EP, timeout=10.0).payload for _ in range(32)]
        assert got == list(range(32))
        with pytest.raises(TimeoutError):
            t.recv(EP, timeout=0.05)

    def test_send_many_lost_acks_do_not_duplicate(self, make):
        t = make(ack_loss=0.5, seed=5)
        for base in range(0, 32, 8):
            t.send_many(EP, [(f"d{i}", base + i) for i in range(8)])
        got = [t.recv(EP, timeout=10.0).payload for _ in range(32)]
        assert got == list(range(32))
        with pytest.raises(TimeoutError):
            t.recv(EP, timeout=0.05)

    def test_scatter_fans_out_per_endpoint_fifo(self, make):
        t = make()
        eps = [("alpha", "beta", f"p{i}") for i in range(3)]
        t.scatter(
            (ep, [(f"d{i}", (k, i)) for i in range(8)])
            for k, ep in enumerate(eps)
        )
        for k, ep in enumerate(eps):
            got = [t.recv(ep, timeout=10.0).payload for _ in range(8)]
            assert got == [(k, i) for i in range(8)]

    def test_scatter_under_loss(self, make):
        t = make(loss=0.4, ack_loss=0.3, seed=9)
        eps = [("alpha", "beta", f"p{i}") for i in range(2)]
        for rank in range(4):
            t.scatter(
                [(ep, [(f"r{rank}d{i}", (rank, i)) for i in range(4)])
                 for ep in eps]
            )
        for ep in eps:
            got = [t.recv(ep, timeout=10.0).payload for _ in range(16)]
            assert got == [(r, i) for r in range(4) for i in range(4)]
        with pytest.raises(TimeoutError):
            t.recv(EP, timeout=0.05)


# ---------------------------------------------------------------------------
# Shared-memory transport specifics — the zero-copy contract
# ---------------------------------------------------------------------------


class TestSharedMemorySpecifics:
    def _make(self, tmp_path, name="s", **kw):
        return SharedMemoryTransport.conformance(
            str(tmp_path / name), LOCATIONS, **kw
        )

    def test_registered_and_crosses_processes(self):
        assert get_transport("shm") is SharedMemoryTransport
        assert SharedMemoryTransport.crosses_processes

    def test_array_payloads_are_mapped_not_pickled(self, tmp_path):
        t = self._make(tmp_path)
        try:
            a = np.arange(4096, dtype=np.float64)
            t.send(EP, "a", a)
            got = t.recv(EP, timeout=10.0).payload
            assert np.array_equal(got, a)
            st = t.stats()
            assert st["segments_created"] >= 1
            assert st["mapped_recvs"] == 1
            assert st["spilled_sends"] == 0
        finally:
            t.close()

    def test_segment_reclaimed_after_consumer_drops_view(self, tmp_path):
        """Dropping the delivered view releases the segment for reuse."""
        t = self._make(tmp_path)
        try:
            view = t.recv_after_send = None
            t.send(EP, "a", np.arange(2048, dtype=np.float64))
            view = t.recv(EP, timeout=10.0).payload
            del view
            gc.collect()  # finalizer queues the release...
            t.send(EP, "b", {"not": "an array"})  # ...this ack carries it
            t.recv(EP, timeout=10.0)
            st = t.stats()
            assert st["segments_released"] >= 1
        finally:
            t.close()

    def test_arena_reuse_over_many_sends(self, tmp_path):
        """Consume-and-release traffic recycles arenas instead of growing."""
        t = self._make(tmp_path)
        try:
            for i in range(32):
                t.send(EP, f"d{i}", np.full(1024, float(i)))
                got = t.recv(EP, timeout=10.0).payload
                assert got[0] == float(i)
                del got
                gc.collect()
            assert t.stats()["segments_created"] <= 4
        finally:
            t.close()

    def test_non_array_payloads_spill_to_pickle(self, tmp_path):
        t = self._make(tmp_path)
        try:
            cases = [
                {"k": [1, 2]},
                "plain string",
                np.array([1], dtype=np.float64)[:0],  # 0 bytes < threshold
                np.array([object()], dtype=object),  # hasobject
            ]
            for i, v in enumerate(cases):
                t.send(EP, f"d{i}", v)
            got = [t.recv(EP, timeout=10.0).payload for _ in cases]
            assert got[0] == cases[0] and got[1] == cases[1]
            assert t.stats()["spilled_sends"] == len(cases)
            assert t.stats()["mapped_recvs"] == 0
        finally:
            t.close()

    def test_broadcast_dedup_writes_one_segment(self, tmp_path):
        """The same array object fanned out is written to shm once."""
        t = self._make(tmp_path)
        try:
            a = np.arange(8192, dtype=np.float64)
            ep2 = ("alpha", "beta", "port1")
            t.send(EP, "a", a)
            t.send(ep2, "a", a)
            g1 = t.recv(EP, timeout=10.0).payload
            g2 = t.recv(ep2, timeout=10.0).payload
            assert np.array_equal(g1, a) and np.array_equal(g2, a)
            st = t.stats()
            assert st["dedup_sends"] >= 1
            assert st["segments_created"] == 1
        finally:
            t.close()

    def test_cross_endpoint_isolation_of_mapped_views(self, tmp_path):
        """Interleaved zero-copy sends never mix segment contents."""
        t = self._make(tmp_path)
        try:
            eps = [("alpha", "beta", f"p{i}") for i in range(3)]
            for i in range(12):
                t.send(eps[i % 3], f"d{i}", np.full(512, float(i)))
            for k, ep in enumerate(eps):
                for i in range(k, 12, 3):
                    got = t.recv(ep, timeout=10.0).payload
                    assert got.shape == (512,)
                    assert np.all(got == float(i))
        finally:
            t.close()

    def test_no_leaked_segments_after_close(self, tmp_path):
        t = self._make(tmp_path)
        ns = t.namespace
        for i in range(4):
            t.send(EP, f"d{i}", np.arange(4096, dtype=np.float64))
        t.recv(EP, timeout=10.0)  # at least one consumer-side mapping too
        t.close()
        assert glob.glob(f"/dev/shm/{ns}-*") == []

    def test_sweep_cleans_up_after_a_crashed_process(self, tmp_path):
        """SIGKILL teardown: the fleet's sweep removes leftover segments."""
        if not os.path.isdir("/dev/shm"):
            pytest.skip("no /dev/shm on this platform")
        authkey = b"crash-teardown-test"
        ns = shm_namespace(authkey)
        addrs = socket_addresses(LOCATIONS, base_dir=str(tmp_path / "c"))
        ctx = multiprocessing.get_context("fork")

        def crash():
            t = SharedMemoryTransport(
                addrs, serve=LOCATIONS, authkey=authkey,
                ack_timeout=2.0, connect_timeout=10.0,
                min_frame_bytes=64,
            )
            t.send(EP, "a", np.arange(4096, dtype=np.float64))
            t.recv(EP, timeout=10.0)
            os._exit(9)  # die without close() — segments stay behind

        p = ctx.Process(target=crash, daemon=True)
        p.start()
        p.join(30.0)
        assert p.exitcode == 9
        assert glob.glob(f"/dev/shm/{ns}-*"), "crash left no segments?"
        assert SharedMemoryTransport.sweep(authkey) >= 1
        assert glob.glob(f"/dev/shm/{ns}-*") == []


# ---------------------------------------------------------------------------
# Socket pickle-5 framing — out-of-band buffers, one fewer copy
# ---------------------------------------------------------------------------


class TestSocketPickle5:
    def test_frame_header_is_tiny_for_array_payloads(self):
        """The pickle stream must carry a stub, not the array body."""
        arr = np.arange(1 << 16, dtype=np.float64)  # 512 KB
        buffers: list = []
        meta = pickle.dumps(
            ("msg", EP, 1, "d", arr),
            protocol=pickle.HIGHEST_PROTOCOL,
            buffer_callback=buffers.append,
        )
        assert sys.getsizeof(meta) < 4096
        assert sum(b.raw().nbytes for b in buffers) == arr.nbytes

    def test_send_side_serialization_saves_one_payload_copy(self):
        """tracemalloc: classic inline pickling allocates the full
        payload body into the pickle stream; the out-of-band path
        allocates only a ~KB header.  That eliminated allocation is
        exactly the 'one fewer copy' this framing buys."""
        import tracemalloc

        arr = np.zeros(1 << 20)  # 8 MB
        frame = ("msg", EP, 1, "d", arr)

        def peak_of(fn):
            tracemalloc.start()
            try:
                fn()
                _, peak = tracemalloc.get_traced_memory()
            finally:
                tracemalloc.stop()
            return peak

        classic = peak_of(
            lambda: pickle.dumps(frame, protocol=pickle.HIGHEST_PROTOCOL)
        )
        buffers: list = []
        oob = peak_of(
            lambda: pickle.dumps(
                frame,
                protocol=pickle.HIGHEST_PROTOCOL,
                buffer_callback=buffers.append,
            )
        )
        assert classic > 0.9 * arr.nbytes  # inline path copies the body
        assert oob < 0.1 * arr.nbytes  # oob header stays tiny
        assert classic - oob > 0.9 * arr.nbytes  # one payload copy saved

    def test_frame_roundtrip_peak_stays_bounded(self, tmp_path):
        """End-to-end over a pipe the receiver still pays its target
        bytearray plus Connection.recv_bytes_into's internal staging
        BytesIO — ~2x nbytes — but never the sender-side pickle copy
        the classic path adds on top (≥3x combined)."""
        import tracemalloc

        t = SocketTransport.conformance(str(tmp_path / "p5"), LOCATIONS)
        try:
            arr = np.zeros(1 << 20)  # 8 MB
            reader, writer = multiprocessing.Pipe(duplex=False)
            frames = []
            th = threading.Thread(
                target=lambda: frames.append(t._recv_frame(reader)),
                daemon=True,
            )
            th.start()
            tracemalloc.start()
            try:
                t._send_frame(writer, ("msg", EP, 1, "d", arr))
                th.join(10.0)
                _, peak = tracemalloc.get_traced_memory()
            finally:
                tracemalloc.stop()
            assert peak < 2.5 * arr.nbytes
            (frame,) = frames
            assert frame[0] == "msg" and np.array_equal(frame[4], arr)
        finally:
            t.close()

    def test_roundtrip_delivers_writable_equal_array(self, tmp_path):
        t = SocketTransport.conformance(str(tmp_path / "rt"), LOCATIONS)
        try:
            arr = np.arange(65536, dtype=np.float64)
            t.send(EP, "a", arr)
            got = t.recv(EP, timeout=10.0).payload
            assert np.array_equal(got, arr)
            got[0] = -1.0  # delivered views are private and writable
            assert arr[0] == 0.0
        finally:
            t.close()
