"""Transport conformance suite — run against every registered transport.

One parametrised test class exercises the :class:`repro.workflow.Transport`
contract (per-endpoint FIFO ordering, exactly-once effect under lossy wires
and lost acks, close-while-blocked raising ``ChannelClosed``, no
cross-endpoint leakage).  The parametrisation iterates the transport
registry, so a future transport gets the whole suite for free by calling
``register_transport`` and implementing ``Transport.conformance``.
"""

from __future__ import annotations

import threading
import time

import pytest

from repro.workflow.transport import (
    TRANSPORTS,
    ChannelClosed,
    HybridTransport,
    InMemoryTransport,
    SocketTransport,
    Transport,
    get_transport,
    register_transport,
    socket_addresses,
)

LOCATIONS = ("alpha", "beta")
EP = ("alpha", "beta", "port0")


@pytest.fixture(params=sorted(TRANSPORTS))
def make(request, tmp_path):
    """Factory building (and tracking for teardown) conformance instances."""
    built: list[Transport] = []

    def factory(locations=LOCATIONS, **faults) -> Transport:
        t = TRANSPORTS[request.param].conformance(
            str(tmp_path / f"t{len(built)}"), locations, **faults
        )
        built.append(t)
        return t

    yield factory
    for t in built:
        t.close()


class TestTransportConformance:
    def test_per_endpoint_fifo_ordering(self, make):
        t = make()
        for i in range(64):
            t.send(EP, f"d{i}", i)
        got = [t.recv(EP, timeout=10.0).payload for _ in range(64)]
        assert got == list(range(64))

    def test_no_cross_endpoint_leakage(self, make):
        t = make()
        eps = [
            ("alpha", "beta", "p0"),
            ("alpha", "beta", "p1"),
            ("beta", "alpha", "p0"),
        ]
        for i in range(8):
            for j, ep in enumerate(eps):
                t.send(ep, f"d{j}", (j, i))
        for j, ep in enumerate(eps):
            got = [t.recv(ep, timeout=10.0).payload for _ in range(8)]
            assert got == [(j, i) for i in range(8)], f"leak into {ep}"

    def test_lossy_wire_delivers_exactly_once_in_order(self, make):
        """At-least-once resend on timeout + idempotent receive."""
        t = make(loss=0.5, seed=7)
        for i in range(32):
            t.send(EP, f"d{i}", i)
        got = [t.recv(EP, timeout=10.0).payload for _ in range(32)]
        assert got == list(range(32))
        with pytest.raises(TimeoutError):
            t.recv(EP, timeout=0.05)  # no duplicate ever surfaces

    def test_lost_acks_do_not_duplicate(self, make):
        """A swallowed ack forces a resend; the receive side deduplicates."""
        t = make(ack_loss=0.5, seed=11)
        for i in range(32):
            t.send(EP, f"d{i}", i)
        got = [t.recv(EP, timeout=10.0).payload for _ in range(32)]
        assert got == list(range(32))
        with pytest.raises(TimeoutError):
            t.recv(EP, timeout=0.05)

    def test_recv_timeout_raises_timeout_error(self, make):
        t = make()
        with pytest.raises(TimeoutError):
            t.recv(EP, timeout=0.05)

    def test_close_while_blocked_raises_channel_closed(self, make):
        t = make()
        caught: list[BaseException] = []
        blocked = threading.Event()

        def receiver():
            blocked.set()
            try:
                t.recv(EP, timeout=30.0)
            except ChannelClosed as e:
                caught.append(e)

        th = threading.Thread(target=receiver, daemon=True)
        th.start()
        assert blocked.wait(5.0)
        time.sleep(0.1)  # let the receiver actually block
        t.close()
        th.join(5.0)
        assert not th.is_alive(), "close() did not unblock the receiver"
        assert caught and isinstance(caught[0], ChannelClosed)

    def test_send_after_close_raises_channel_closed(self, make):
        t = make()
        t.close()
        with pytest.raises(ChannelClosed):
            t.send(EP, "d", 1)

    def test_pending_messages_drain_before_closed_raises(self, make):
        t = make()
        # send() blocks until the message is delivered/acked, so all three
        # are already in the inbox when close() lands.
        for i in range(3):
            t.send(EP, f"d{i}", i)
        t.close()
        got = [t.recv(EP, timeout=10.0).payload for _ in range(3)]
        assert got == [0, 1, 2]
        with pytest.raises(ChannelClosed):
            t.recv(EP, timeout=5.0)

    def test_close_is_idempotent(self, make):
        t = make()
        t.close()
        t.close()

    def test_concurrent_senders_on_distinct_endpoints(self, make):
        t = make()
        eps = [("alpha", "beta", f"p{i}") for i in range(4)]
        errs: list[BaseException] = []

        def sender(ep):
            try:
                for i in range(16):
                    t.send(ep, f"d{i}", i)
            except BaseException as e:  # noqa: BLE001
                errs.append(e)

        threads = [
            threading.Thread(target=sender, args=(ep,), daemon=True)
            for ep in eps
        ]
        for th in threads:
            th.start()
        for th in threads:
            th.join(30.0)
        assert not errs
        for ep in eps:
            got = [t.recv(ep, timeout=10.0).payload for _ in range(16)]
            assert got == list(range(16))


# ---------------------------------------------------------------------------
# Registry + construction specifics (not part of the per-transport contract)
# ---------------------------------------------------------------------------


class TestRegistry:
    def test_builtins_registered(self):
        assert get_transport("memory") is InMemoryTransport
        assert get_transport("socket") is SocketTransport

    def test_unknown_name(self):
        with pytest.raises(KeyError, match="unknown transport"):
            get_transport("carrier-pigeon")

    def test_duplicate_registration_rejected(self):
        with pytest.raises(ValueError, match="already registered"):
            register_transport("memory", InMemoryTransport)

    def test_crosses_processes_flags(self):
        assert not InMemoryTransport.crosses_processes
        assert SocketTransport.crosses_processes


class TestSocketSpecifics:
    def test_addresses_are_per_location_and_stable(self, tmp_path):
        a = socket_addresses(["x", "y", "z"], base_dir=tmp_path)
        b = socket_addresses(["z", "y", "x"], base_dir=tmp_path)
        assert a == b
        assert len(set(a.values())) == 3

    def test_serve_requires_address(self, tmp_path):
        addrs = socket_addresses(["x"], base_dir=tmp_path)
        with pytest.raises(KeyError, match="serve locations"):
            SocketTransport(addrs, serve=("ghost",))

    def test_resend_stats_recorded_under_loss(self, tmp_path):
        t = SocketTransport.conformance(
            str(tmp_path), LOCATIONS, loss=0.5, seed=3
        )
        try:
            for i in range(16):
                t.send(EP, f"d{i}", i)
            for _ in range(16):
                t.recv(EP, timeout=10.0)
            stats = t.stats()
            assert stats["dropped"] > 0
            assert stats["resends"] >= stats["dropped"]
            assert stats["delivered"] == 16
        finally:
            t.close()

    def test_unreachable_destination_raises(self, tmp_path):
        addrs = socket_addresses(LOCATIONS, base_dir=tmp_path)
        t = SocketTransport(addrs, serve=("alpha",), connect_timeout=0.3)
        try:
            with pytest.raises(ChannelClosed, match="cannot connect"):
                t.send(("alpha", "beta", "p"), "d", 1)
        finally:
            t.close()


class TestHybrid:
    """The co-residency composite used by multi-location worker processes."""

    @pytest.fixture
    def hybrid(self, tmp_path):
        remote = SocketTransport.conformance(
            str(tmp_path), ("alpha", "beta", "gamma")
        )
        t = HybridTransport(remote, ("alpha", "beta"))
        yield t
        t.close()

    def test_local_endpoints_never_touch_the_wire(self, hybrid):
        hybrid.send(("alpha", "beta", "p"), "d", 42)
        assert hybrid.recv(("alpha", "beta", "p"), timeout=5.0).payload == 42
        assert hybrid.stats()["remote"]["sent"] == 0
        assert hybrid.stats()["local"]["sent"] == 1

    def test_cross_endpoints_use_the_remote_wire(self, hybrid):
        hybrid.send(("alpha", "gamma", "p"), "d", 7)
        assert (
            hybrid.recv(("alpha", "gamma", "p"), timeout=5.0).payload == 7
        )
        assert hybrid.stats()["remote"]["sent"] == 1

    def test_close_closes_both_sides(self, hybrid):
        hybrid.close()
        with pytest.raises(ChannelClosed):
            hybrid.send(("alpha", "beta", "p"), "d", 1)
        with pytest.raises(ChannelClosed):
            hybrid.send(("alpha", "gamma", "p"), "d", 1)
