"""Channel fault injection: per-endpoint RNG streams must be uncorrelated.

Regression test for the correlated-fault bug where every ``Channel``
defaulted to ``random.Random(0)``, making all endpoints drop/delay in
lockstep (which silently weakened every fault-tolerance experiment).
"""

from __future__ import annotations

from repro.workflow.channels import Channel, ChannelRegistry, endpoint_rng


def _pattern(ch: Channel, n: int = 64) -> list[bool]:
    return [ch._rng.random() < 0.5 for _ in range(n)]


def test_endpoints_in_one_registry_are_decorrelated():
    reg = ChannelRegistry(seed=0, drop_prob=0.5)
    pats = [
        _pattern(reg.channel("l0", f"l{i}", f"p{i}")) for i in range(1, 5)
    ]
    assert len({tuple(p) for p in pats}) == len(pats), (
        "distinct endpoints produced identical fault patterns"
    )


def test_same_seed_reproduces_same_faults():
    p1 = _pattern(ChannelRegistry(seed=3).channel("a", "b", "p"))
    p2 = _pattern(ChannelRegistry(seed=3).channel("a", "b", "p"))
    assert p1 == p2


def test_registry_seed_changes_every_stream():
    p1 = _pattern(ChannelRegistry(seed=0).channel("a", "b", "p"))
    p2 = _pattern(ChannelRegistry(seed=1).channel("a", "b", "p"))
    assert p1 != p2


def test_endpoint_rng_mixes_all_triple_components():
    base = endpoint_rng(0, ("a", "b", "p")).random()
    assert base != endpoint_rng(0, ("x", "b", "p")).random()
    assert base != endpoint_rng(0, ("a", "x", "p")).random()
    assert base != endpoint_rng(0, ("a", "b", "x")).random()


def test_dropped_messages_differ_across_channels():
    reg = ChannelRegistry(seed=0, drop_prob=0.5)
    outcomes = {}
    for i in range(4):
        ch = reg.channel("src", f"dst{i}", "p")
        outcomes[i] = tuple(ch.put(f"d{j}", j) for j in range(32))
    assert len(set(outcomes.values())) > 1
