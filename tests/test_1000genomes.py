"""§6 / Appendix B end-to-end: the 1000 Genomes workflow with numeric step
bodies, run decentralised, optimised vs unoptimised equivalence."""

import numpy as np

from repro.core import encode, optimize
from repro.core.compile import compile_bundles
from repro.core.translate import genomes_1000
from repro.workflow import Runtime, ThreadedRuntime


def _numeric_fns(inst, initial, rng_seed=0):
    """Plausible numeric bodies: individuals parse arrays, merge stacks,
    sifting filters, MO/F reduce.  ``s0`` is the paper's auxiliary driver
    step: its body "loads" the initial data (here: from the closure, in the
    reference implementation: from local files) and the encoding's sends
    distribute it."""
    fns = {}

    for s in inst.workflow.steps:
        outs = inst.out_data(s)
        if s == "s0":
            def f(inputs, outs=outs):
                return {o: initial[("l^d", o)] for o in outs}
        elif s.startswith("sI_"):
            def f(inputs, outs=outs):
                (d,) = list(inputs.values())
                return {o: np.sort(np.asarray(d))[:8] for o in outs}
        elif s == "sIM":
            def f(inputs, outs=outs):
                stacked = np.stack([inputs[k] for k in sorted(inputs)])
                return {o: stacked.mean(axis=0) for o in outs}
        elif s == "sSF":
            def f(inputs, outs=outs):
                (d,) = list(inputs.values())
                return {o: np.asarray(d)[np.asarray(d) > 0.25] for o in outs}
        else:  # sMO_*, sF_*: reduce to a statistic
            def f(inputs, outs=outs):
                total = sum(float(np.sum(np.asarray(v))) for v in inputs.values())
                return {o: total for o in outs}
        fns[s] = f
    return fns


def _init_payloads(inst, seed=0):
    rng = np.random.default_rng(seed)
    return {
        ("l^d", d): rng.random(16) for d in inst.g("l^d")
    }


def test_end_to_end_numeric():
    inst = genomes_1000(n=4, m=3, a=2, b=2, c=2)
    w = encode(inst)
    o, stats = optimize(w)
    assert stats.removed > 0
    init = _init_payloads(inst)
    fns = _numeric_fns(inst, init)

    rt_plain = Runtime(w, fns, initial_payloads=dict(init))
    rt_plain.run()
    rt_opt = Runtime(o, fns, initial_payloads=dict(init))
    rt_opt.run()

    # optimisation is value-preserving: same payloads everywhere
    for loc in w.locations():
        a = rt_plain.location_data(loc)
        b = rt_opt.location_data(loc)
        assert set(a) == set(b), loc
        for k in a:
            np.testing.assert_array_equal(
                np.asarray(a[k], dtype=object) if a[k] is None else np.asarray(a[k]),
                np.asarray(b[k], dtype=object) if b[k] is None else np.asarray(b[k]),
            )


def test_decentralised_matches_reduction_runtime():
    inst = genomes_1000(n=4, m=3, a=2, b=2, c=2)
    o, _ = optimize(encode(inst))
    init = _init_payloads(inst)
    fns = _numeric_fns(inst, init)

    rt = Runtime(o, fns, initial_payloads=dict(init))
    rt.run()
    trt = ThreadedRuntime(
        compile_bundles(o, fns), initial_payloads=dict(init), timeout_s=30
    )
    data = trt.run()
    for loc in o.locations():
        got = data[loc]
        want = rt.location_data(loc)
        assert set(got) == set(want)
        for k in want:
            if want[k] is None:
                assert got[k] is None
            else:
                np.testing.assert_array_equal(np.asarray(got[k]), np.asarray(want[k]))


def test_communication_savings_scale_with_m():
    """App. B: savings appear exactly when m > b (and m > c)."""
    small = genomes_1000(n=2, m=2, a=2, b=2, c=2)
    _, s_small = optimize(encode(small))
    big = genomes_1000(n=2, m=6, a=2, b=2, c=2)
    _, s_big = optimize(encode(big))
    assert s_big.removed > s_small.removed
