"""Compilation at scale: incremental placement scoring + large-DAG smoke.

Two halves:

* **Scorer differential** — the incremental
  :class:`~repro.sched.incremental.PlacementScorer` must return exactly the
  ``(makespan, cross_bytes)`` that the tree path
  (:func:`~repro.sched.place.evaluate_placement` =
  ``simulate(rewrite(encode(I under M)))``) reports, for the initial
  mapping and after arbitrary sequences of single-step moves, across rule
  lists, networks and cost models.  This is what makes the budgeted local
  search trustworthy: every accepted move was scored on exactly the plan
  that will be lowered.

* **Scale smoke** (``@pytest.mark.slow``) — a 2,000-step DAG compiles end
  to end (trace → optimize → schedule → lower on ``inprocess``) under a
  generous wall-clock bound, and ``auto_placement`` on a 500-step DAG
  finishes in under 30 s.  Scale regressions fail CI loudly instead of
  silently.
"""

from __future__ import annotations

import random
import time

import pytest
from conftest import identity_step_fns

from repro import swirl
from repro.core.randgen import random_layered_instance
from repro.sched import (
    CostModel,
    NetworkModel,
    SizeModel,
    auto_placement,
    evaluate_placement,
    refine_placement,
)
from repro.sched.incremental import PlacementScorer, UnsupportedRules
from repro.sched.place import movable_steps
from test_differential import random_instance


# ---------------------------------------------------------------------------
# Incremental scorer ≡ tree evaluation
# ---------------------------------------------------------------------------


class TestScorerDifferential:
    NETWORKS = [
        NetworkModel.preset("uniform"),
        NetworkModel.preset("two-rack"),
    ]

    @pytest.mark.parametrize("chunk", range(5))
    def test_score_matches_tree_path_under_random_moves(self, chunk):
        for i in range(8):
            rng = random.Random(1000 * chunk + i)
            inst = random_instance(rng)
            network = self.NETWORKS[(chunk + i) % 2].bind(inst.locations)
            sizes = SizeModel(default_bytes=rng.choice([1024, 1 << 18]))
            costs = CostModel(default_exec_s=rng.choice([1e-3, 5e-3]))
            rules = rng.choice([(), ("R1R2",), ("R1R2", "R3")])
            scorer = PlacementScorer(
                inst, network, sizes=sizes, costs=costs, rules=rules
            )
            mapping = {s: tuple(ls) for s, ls in inst.mapping.items()}
            scorer.reset(mapping)
            locs = sorted(inst.locations)
            movable = movable_steps(inst)
            for _ in range(5):
                sim = evaluate_placement(
                    inst, mapping, network,
                    sizes=sizes, costs=costs, rules=rules,
                )
                makespan, cross = scorer.score()
                assert cross == sim.cross_bytes
                assert makespan == pytest.approx(sim.makespan, abs=1e-12)
                assert scorer.cross_bytes_only() == sim.cross_bytes
                if not movable:
                    break
                s = rng.choice(movable)
                target = (rng.choice(locs),)
                mapping[s] = target
                scorer.move(s, target)

    def test_unsupported_rules_rejected(self):
        inst = random_instance(random.Random(0))
        with pytest.raises(UnsupportedRules):
            PlacementScorer(
                inst,
                NetworkModel.preset("uniform"),
                sizes=SizeModel(),
                costs=CostModel(),
                rules=("R3",),
            )

    def test_refine_falls_back_for_unsupported_rules(self):
        """Rule lists without a flat replay still refine (tree path)."""
        inst = random_instance(random.Random(3))
        mapping = {s: tuple(ls) for s, ls in inst.mapping.items()}
        refined, sim = refine_placement(
            inst, mapping, NetworkModel.preset("uniform"),
            sizes=SizeModel(), costs=CostModel(), rules=("R3",),
        )
        fresh = evaluate_placement(
            inst, refined, NetworkModel.preset("uniform"),
            sizes=SizeModel(), costs=CostModel(), rules=("R3",),
        )
        assert sim.makespan == pytest.approx(fresh.makespan)
        assert sim.cross_bytes == fresh.cross_bytes

    def test_refine_is_deterministic(self):
        inst = random_layered_instance(80, n_locations=3, seed=5)
        mapping = {s: tuple(ls) for s, ls in inst.mapping.items()}
        kw = dict(
            sizes=SizeModel(default_bytes=1 << 16),
            costs=CostModel(default_exec_s=1e-3),
        )
        net = NetworkModel.preset("two-rack")
        a1, s1 = refine_placement(inst, mapping, net, **kw)
        a2, s2 = refine_placement(inst, mapping, net, **kw)
        assert a1 == a2
        assert s1.makespan == s2.makespan

    def test_refine_never_worse_than_start(self):
        for seed in range(6):
            inst = random_instance(random.Random(seed + 40))
            net = NetworkModel.preset("two-rack").bind(inst.locations)
            kw = dict(
                sizes=SizeModel(default_bytes=1 << 18),
                costs=CostModel(default_exec_s=1e-3),
            )
            mapping = {s: tuple(ls) for s, ls in inst.mapping.items()}
            start = evaluate_placement(inst, mapping, net, **kw)
            refined, sim = refine_placement(inst, mapping, net, **kw)
            # The search only accepts strict score improvements, and the
            # scorer is exact — the final (makespan, bytes) can never be
            # lexicographically worse than the starting point's.
            assert (sim.makespan, sim.cross_bytes) <= (
                start.makespan,
                start.cross_bytes,
            )

    def test_max_evals_budget_is_respected(self):
        """With a one-candidate budget the search stops immediately."""
        inst = random_layered_instance(60, n_locations=3, seed=9)
        mapping = {s: tuple(ls) for s, ls in inst.mapping.items()}
        net = NetworkModel.preset("uniform")
        kw = dict(sizes=SizeModel(), costs=CostModel())
        budget_1, _ = refine_placement(
            inst, mapping, net, max_evals=1, **kw
        )
        assert budget_1 == mapping  # no candidate was ever scored


# ---------------------------------------------------------------------------
# Scale smoke — loud CI failure on compile-time regression
# ---------------------------------------------------------------------------


@pytest.mark.slow
class TestLargeDagSmoke:
    def test_2000_step_dag_compiles_end_to_end(self):
        """trace → optimize(R1R2+R3) → schedule → lower(inprocess) →
        compile on a 2,000-step DAG, under a generous wall-clock bound."""
        bound_s = 120.0
        inst = random_layered_instance(
            2000, n_locations=4, seed=0, p_spatial=0.1
        )
        t0 = time.perf_counter()
        plan = swirl.trace(inst).optimize(("R1R2", "R3"))
        sched = plan.schedule(
            NetworkModel.preset("two-rack"),
            sizes=SizeModel(default_bytes=1 << 16),
            costs=CostModel(default_exec_s=1e-3),
        )
        exe = sched.lower("inprocess").compile(identity_step_fns(inst))
        elapsed = time.perf_counter() - t0
        assert elapsed < bound_s, (
            f"2000-step compile took {elapsed:.1f}s (bound {bound_s}s) — "
            "the compilation pipeline regressed at scale"
        )
        assert sched.schedule_report is not None
        assert len(sched.steps()) == 2000
        assert exe.plan.system.total_actions() > 2000

    def test_auto_placement_500_steps_wall_clock(self):
        """The uninstrumented target is < 30 s (recorded by the
        ``compile/auto_placement_500steps`` benchmark row, ~21 s); this CI
        gate runs on the coverage-instrumented 3.12 leg where the C tracer
        roughly doubles pure-Python hot loops, so it asserts 2x the target
        — still an order of magnitude below the pre-incremental-scorer
        cost, which made this size infeasible outright."""
        inst = random_layered_instance(
            500, n_locations=4, seed=1, p_spatial=0.1
        )
        t0 = time.perf_counter()
        report = auto_placement(
            inst,
            NetworkModel.preset("two-rack"),
            sizes=SizeModel(default_bytes=1 << 18),
            costs=CostModel(default_exec_s=2e-3),
        )
        elapsed = time.perf_counter() - t0
        assert elapsed < 60.0, (
            f"auto_placement on 500 steps took {elapsed:.1f}s — the "
            "incremental scorer regressed (uninstrumented target: <30s)"
        )
        assert report.predicted.cross_bytes <= report.baseline.cross_bytes
