"""Distribution hints (H1 attention / H2 MoE): numerically identical to the
baseline paths on a degenerate 1×1 mesh (the 512-device behaviour is
exercised by the dry-run)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import Model, ModelConfig, MoECfg
from repro.models.hints import ShardHints, get_hints, set_hints
from repro.models.layers import sdpa


@pytest.fixture
def unit_mesh():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    set_hints(ShardHints(mesh=mesh, dp_axes=("data",)))
    yield mesh
    set_hints(None)


def test_stride_chunks_match_contiguous():
    key = jax.random.key(0)
    q = jax.random.normal(key, (2, 64, 4, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 64, 2, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 64, 2, 16))
    a = sdpa(q, k, v, causal=True, q_chunk=16, stride_chunks=False)
    b = sdpa(q, k, v, causal=True, q_chunk=16, stride_chunks=True)
    c = sdpa(q, k, v, causal=True, q_chunk=64)  # single chunk reference
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=1e-5)
    np.testing.assert_allclose(np.asarray(b), np.asarray(c), atol=1e-5)


def test_hinted_model_matches_baseline(unit_mesh):
    cfg = ModelConfig(
        name="hinted", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
        d_ff=0, vocab=128, dtype="float32", remat=False,
        pattern=(("attn", "moe"),),
        moe=MoECfg(n_experts=4, top_k=2, d_expert=16, n_shared=1,
                   capacity_factor=4.0),
    )
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}

    assert get_hints() is not None
    with unit_mesh:
        loss_h, metrics_h = jax.jit(m.loss)(params, batch)
    set_hints(None)
    loss_b, metrics_b = jax.jit(m.loss)(params, batch)

    assert float(jnp.abs(loss_h - loss_b)) < 1e-5
    assert float(jnp.abs(metrics_h["aux"] - metrics_b["aux"])) < 1e-5


def test_hinted_grads_match_baseline(unit_mesh):
    cfg = ModelConfig(
        name="hinted-g", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
        d_ff=0, vocab=128, dtype="float32", remat=False,
        pattern=(("attn", "moe"),),
        moe=MoECfg(n_experts=2, top_k=1, d_expert=16, capacity_factor=4.0),
    )
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}

    with unit_mesh:
        g_h = jax.jit(jax.grad(lambda p: m.loss(p, batch)[0]))(params)
    set_hints(None)
    g_b = jax.jit(jax.grad(lambda p: m.loss(p, batch)[0]))(params)
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), g_h, g_b
    )
    assert max(jax.tree.leaves(diffs)) < 1e-4
