"""Workflow runtime: effects, fault tolerance, checkpoints, equivalence."""

import threading
import time

import pytest

from repro.core import encode, optimize
from repro.core.compile import compile_bundles
from repro.core.parser import parse_system
from repro.core.translate import genomes_1000
from repro.workflow import (
    Checkpoint,
    FlakyFn,
    PermanentError,
    RetryPolicy,
    Runtime,
    SlowFn,
    SpeculationPolicy,
    ThreadedRuntime,
    TransientError,
    WorkflowDeadlock,
)

from conftest import identity_step_fns


def _genomes(n=3, m=2):
    inst = genomes_1000(n=n, m=m, a=2, b=2, c=2)
    w, _ = optimize(encode(inst))
    fns = identity_step_fns(inst)
    init = {("l^d", d): f"raw:{d}" for d in inst.g("l^d")}
    return inst, w, fns, init


def test_runtime_executes_all_steps():
    inst, w, fns, init = _genomes()
    rt = Runtime(w, fns, initial_payloads=init)
    stats = rt.run()
    assert stats.execs == len(inst.workflow.steps)
    # the MO location holds its inputs (copies — COMM does not consume)
    assert "d^IM" in rt.location_data("l^MO_1")
    assert "d^IM" in rt.location_data("l^IM")


def test_runtime_threaded_equivalence():
    inst, w, fns, init = _genomes()
    rt = Runtime(w, fns, initial_payloads=init)
    rt.run()
    trt = ThreadedRuntime(
        compile_bundles(w, fns), initial_payloads=init, timeout_s=20
    )
    data = trt.run()
    for loc in w.locations():
        assert data[loc] == rt.location_data(loc), loc


def test_retry_recovers_transient_failures():
    inst, w, fns, init = _genomes()
    fns = dict(fns)
    fns["sIM"] = FlakyFn(fns["sIM"], failures=2)
    rt = Runtime(w, fns, initial_payloads=init, retry=RetryPolicy(max_retries=3))
    stats = rt.run()
    assert stats.retries == 2


def test_retry_exhaustion_raises():
    inst, w, fns, init = _genomes()
    fns = dict(fns)
    fns["sIM"] = FlakyFn(fns["sIM"], failures=10)
    rt = Runtime(w, fns, initial_payloads=init, retry=RetryPolicy(max_retries=2))
    with pytest.raises(TransientError):
        rt.run()


def test_permanent_error_not_retried():
    inst, w, fns, init = _genomes()
    fns = dict(fns)
    fns["sIM"] = FlakyFn(fns["sIM"], failures=5, exc=PermanentError)
    rt = Runtime(w, fns, initial_payloads=init, retry=RetryPolicy(max_retries=5))
    with pytest.raises(PermanentError):
        rt.run()
    assert fns["sIM"].calls == 1


def test_straggler_speculation():
    inst, w, fns, init = _genomes()
    fns = dict(fns)
    fns["sIM"] = SlowFn(fns["sIM"], delay_s=1.0, slow_calls=1)
    rt = Runtime(
        w, fns, initial_payloads=init,
        expected_s={"sIM": 0.02},
        speculation=SpeculationPolicy(enabled=True, factor=2.0),
    )
    t0 = time.monotonic()
    stats = rt.run()
    assert stats.speculations >= 1
    assert time.monotonic() - t0 < 1.0  # backup copy won


def test_deadlock_detected():
    w = parse_system("<a,{},recv(p,b,a)> | <b,{},recv(q,a,b)>")
    rt = Runtime(w, {})
    with pytest.raises(WorkflowDeadlock):
        rt.run()


def test_checkpoint_restore_resumes(tmp_path):
    inst, w, fns, init = _genomes(n=4, m=3)
    path = tmp_path / "wf.ckpt"
    rt = Runtime(
        w, fns, initial_payloads=init,
        checkpoint_every=2, checkpoint_path=path,
    )
    stats = rt.run()
    assert stats.checkpoints >= 1

    ckpt = Checkpoint.load(path)
    rt2 = Runtime.restore(ckpt, fns)
    stats2 = rt2.run()
    # resumed run finishes the remaining steps and ends in the same payloads
    for loc in w.locations():
        assert rt2.location_data(loc) == rt.location_data(loc)
    assert stats2.execs <= stats.execs


def test_checkpoint_is_consistent_snapshot(tmp_path):
    """A checkpoint parses back to a reachable system (term = program ctr)."""
    inst, w, fns, init = _genomes()
    path = tmp_path / "wf.ckpt"
    rt = Runtime(w, fns, initial_payloads=init, checkpoint_every=1,
                 checkpoint_path=path)
    rt.run()
    ckpt = Checkpoint.load(path)
    sys2 = ckpt.system  # must parse
    assert set(sys2.locations()) == set(w.locations())


def test_exec_concurrency():
    """Independent execs run in parallel on the pool."""
    inst = genomes_1000(n=4, m=2, a=4, b=2, c=2)
    w, _ = optimize(encode(inst))
    fns = identity_step_fns(inst)
    init = {("l^d", d): f"raw:{d}" for d in inst.g("l^d")}
    running = []
    peak = []
    lock = threading.Lock()

    def slow_wrap(fn):
        def wrapped(inputs):
            with lock:
                running.append(1)
                peak.append(len(running))
            time.sleep(0.1)
            out = fn(inputs)
            with lock:
                running.pop()
            return out

        return wrapped

    for i in (1, 2, 3, 4):
        fns[f"sI_{i}"] = slow_wrap(fns[f"sI_{i}"])
    rt = Runtime(w, fns, initial_payloads=init, max_workers=4)
    rt.run()
    assert max(peak) >= 2  # individuals ran concurrently
