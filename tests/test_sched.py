"""The placement & data-movement scheduler (``repro.sched``).

Covers the network cost model and its presets, the payload/cost estimators,
the makespan simulator (timing model, channel matching, contention), the
placement search, and the ``Plan.schedule`` / ``placement="auto"``
integration — including the acceptance criteria: ≥30% cross-location-byte
reduction vs round-robin on 1000 Genomes under ``two-rack``, simulator
ordering matching threaded-backend wall-clock ordering (timing-sensitive,
``@pytest.mark.slow``, generous bounds), and behaviour preservation
(bisimulation certificate + identical results on every registered backend)
for scheduled plans.
"""

from __future__ import annotations

import time

import pytest

from repro import swirl
from repro.core.compile import StepMeta
from repro.core.syntax import Exec, Recv, Send, config, seq, system
from repro.core.translate import TrainPipelineTranslator, genomes_1000
from repro.sched import (
    CostModel,
    Link,
    NetworkModel,
    ScheduleReport,
    SimulationError,
    SizeModel,
    auto_placement,
    greedy_placement,
    round_robin_placement,
    simulate,
)

EDGES = {
    "preprocess": ["train_a", "train_b"],
    "train_a": ["evaluate"],
    "train_b": ["evaluate"],
    "evaluate": ["report"],
    "report": [],
}
MAPPING = {
    "preprocess": ("cpu0",),
    "train_a": ("gpu0",),
    "train_b": ("gpu1",),
    "evaluate": ("gpu0",),
    "report": ("cpu0",),
}


def quickstart_steps():
    return {
        "preprocess": lambda inp: {"d^preprocess": list(range(10))},
        "train_a": lambda inp: {"d^train_a": sum(inp["d^preprocess"])},
        "train_b": lambda inp: {"d^train_b": max(inp["d^preprocess"])},
        "evaluate": lambda inp: {
            "d^evaluate": inp["d^train_a"] + inp["d^train_b"]
        },
        "report": lambda inp: {},
    }


# ---------------------------------------------------------------------------
# Network model
# ---------------------------------------------------------------------------


class TestNetworkModel:
    def test_link_transfer_math(self):
        link = Link(bandwidth=1000.0, latency=0.5)
        assert link.transfer_s(1000) == pytest.approx(1.5)
        assert Link(float("inf"), 0.25).transfer_s(10**12) == 0.25

    def test_bad_link_rejected(self):
        with pytest.raises(ValueError):
            Link(bandwidth=0.0)
        with pytest.raises(ValueError):
            Link(bandwidth=1.0, latency=-1.0)

    def test_intra_location_is_free(self):
        net = NetworkModel.preset("uniform", latency=1.0)
        assert net.transfer_s(10**9, "a", "a") == 0.0
        assert net.transfer_s(0, "a", "b") == pytest.approx(1.0)

    def test_two_rack_bind_splits_sorted_locations(self):
        net = NetworkModel.preset("two-rack").bind(["d", "a", "c", "b"])
        assert net.group_of("a") == "rack0" and net.group_of("b") == "rack0"
        assert net.group_of("c") == "rack1" and net.group_of("d") == "rack1"
        intra = net.transfer_s(0, "a", "b")
        inter = net.transfer_s(0, "a", "c")
        assert intra < inter

    def test_two_rack_explicit_racks(self):
        net = NetworkModel.preset(
            "two-rack", racks={"rack0": ["x"], "rack1": ["y"]}
        )
        assert net.group_of("x") == "rack0"
        # explicit racks need no bind; bind is a no-op
        assert net.bind(["x", "y"]).group_of("y") == "rack1"

    def test_cpu_accelerator_groups_by_name(self):
        net = NetworkModel.preset("cpu+accelerator").bind(
            ["cpu0", "gpu0", "gpu1"]
        )
        assert net.group_of("cpu0") == "cpu"
        assert net.group_of("gpu0") == "accel"
        assert net.transfer_s(10**6, "gpu0", "gpu1") < net.transfer_s(
            10**6, "cpu0", "gpu0"
        )

    def test_cpu_accelerator_explicit_cpu(self):
        net = NetworkModel.preset("cpu+accelerator", cpu=["left"])
        assert net.group_of("left") == "cpu"
        assert net.group_of("anything-else") == "accel"

    def test_explicit_pair_link_wins(self):
        net = NetworkModel(
            default=Link(1.0, 10.0),
            links={("a", "b"): Link(float("inf"), 0.0)},
        )
        assert net.transfer_s(100, "a", "b") == 0.0
        assert net.transfer_s(100, "b", "a") == pytest.approx(110.0)

    def test_unknown_preset_rejected(self):
        with pytest.raises(ValueError, match="unknown network preset"):
            NetworkModel.preset("warp")
        with pytest.raises(TypeError, match="unknown arguments"):
            NetworkModel.preset("uniform", racks={})

    def test_overlapping_groups_rejected(self):
        with pytest.raises(ValueError, match="in groups"):
            NetworkModel(
                groups={"g1": frozenset({"a"}), "g2": frozenset({"a"})}
            )


# ---------------------------------------------------------------------------
# Estimators
# ---------------------------------------------------------------------------


class TestEstimators:
    def test_size_model_defaults_and_overrides(self):
        m = SizeModel(default_bytes=7, sizes={"d": 100})
        assert m.bytes_of("d") == 100
        assert m.bytes_of("other") == 7
        assert m.updated({"e": 5}).bytes_of("e") == 5

    def test_from_step_metas_reads_output_bytes(self):
        metas = {
            "s1": StepMeta(fn=lambda i: {}, output_bytes={"d1": 42}),
            "s2": lambda i: {},  # plain callables carry no sizes
        }
        m = SizeModel.from_step_metas(metas, default_bytes=9)
        assert m.bytes_of("d1") == 42
        assert m.bytes_of("dX") == 9

    def test_from_payloads_measures_nbytes(self):
        np = pytest.importorskip("numpy")
        m = SizeModel.from_payloads(
            {("loc", "arr"): np.zeros(10, dtype=np.float64), "plain": 3}
        )
        assert m.bytes_of("arr") == 80
        assert m.bytes_of("plain") > 0

    def test_for_shape_uses_configs_shapes(self):
        from repro.configs.shapes import SHAPES

        m = SizeModel.for_shape("decode_32k", d_model=128)
        # decode moves one row per sequence: batch × d_model × bf16
        assert m.default_bytes == SHAPES["decode_32k"].global_batch * 128 * 2
        m2 = SizeModel.for_shape("train_4k", d_model=8)
        s = SHAPES["train_4k"]
        assert m2.default_bytes == s.seq_len * s.global_batch * 8 * 2
        with pytest.raises(TypeError, match="d_model"):
            SizeModel.for_shape("train_4k")

    def test_cost_model_from_metas(self):
        metas = {
            "fast": StepMeta(fn=lambda i: {}, expected_seconds=0.25),
            "plain": lambda i: {},
        }
        c = CostModel.from_step_metas(metas, default_exec_s=1.0)
        assert c.exec_s("fast") == 0.25
        assert c.exec_s("plain") == 1.0


# ---------------------------------------------------------------------------
# Makespan simulator
# ---------------------------------------------------------------------------


def two_location_chain():
    """a: exec(s1).send — b: recv.exec(s2)."""
    return system(
        config(
            "a",
            {"x"},
            seq(
                Exec("s1", frozenset({"x"}), frozenset({"y"}), ("a",)),
                Send("y", "p", "a", "b"),
            ),
        ),
        config(
            "b",
            set(),
            seq(
                Recv("p", "a", "b"),
                Exec("s2", frozenset({"y"}), frozenset({"z"}), ("b",)),
            ),
        ),
    )


class TestSimulate:
    def test_chain_timing(self):
        sim = simulate(
            two_location_chain(),
            network=NetworkModel.preset(
                "uniform", bandwidth=1000.0, latency=0.5
            ),
            sizes=SizeModel(default_bytes=1000),
            costs=CostModel(default_exec_s=1.0),
        )
        # s1: [0,1]; transfer 0.5 + 1000/1000 = 1.5; s2: [2.5, 3.5]
        assert sim.makespan == pytest.approx(3.5)
        assert sim.cross_bytes == 1000
        assert sim.bytes_by_pair == {("a", "b"): 1000}
        assert sim.comm_seconds == pytest.approx(1.5)
        assert sim.exec_seconds == pytest.approx(2.0)
        assert sim.critical_path[0].startswith("exec(s1)")
        assert sim.critical_path[-1].startswith("exec(s2)")
        assert {e.kind for e in sim.timelines["a"]} == {"exec", "send"}

    def test_local_transfer_costs_nothing(self):
        w = system(
            config(
                "a",
                {"x"},
                seq(
                    Exec("s1", frozenset({"x"}), frozenset({"y"}), ("a",)),
                    Send("y", "p", "a", "a"),
                    Recv("p", "a", "a"),
                ),
            )
        )
        sim = simulate(
            w,
            network=NetworkModel.preset("uniform", latency=10.0),
            costs=CostModel(default_exec_s=1.0),
        )
        assert sim.makespan == pytest.approx(1.0)
        assert sim.cross_bytes == 0

    def test_unmatched_recv_raises(self):
        w = system(config("b", set(), Recv("p", "a", "b")))
        with pytest.raises(SimulationError, match="no matching send"):
            simulate(w)

    def test_exec_slots_serialise_parallel_work(self):
        from repro.core.syntax import par

        w = system(
            config(
                "a",
                {"x"},
                par(
                    Exec("s1", frozenset({"x"}), frozenset(), ("a",)),
                    Exec("s2", frozenset({"x"}), frozenset(), ("a",)),
                ),
            )
        )
        costs = CostModel(default_exec_s=1.0)
        assert simulate(w, costs=costs).makespan == pytest.approx(1.0)
        assert simulate(
            w, costs=costs, exec_slots=1
        ).makespan == pytest.approx(2.0)

    def test_synchronised_exec_waits_for_all_locations(self):
        act = Exec("sync", frozenset(), frozenset({"o"}), ("a", "b"))
        w = system(
            config("a", {"y"}, seq(Send("y", "p", "a", "b"), act)),
            config("b", set(), seq(Recv("p", "a", "b"), act)),
        )
        net = NetworkModel.preset(
            "uniform", bandwidth=float("inf"), latency=2.0
        )
        sim = simulate(w, network=net, costs=CostModel(default_exec_s=1.0))
        # b is only ready after the 2s transfer; the exec spans [2, 3].
        assert sim.makespan == pytest.approx(3.0)

    def test_rewriting_never_hurts_simulated_cost(self):
        inst = genomes_1000(n=4, m=3, a=2, b=2, c=2)
        raw = swirl.trace(inst)
        opt = raw.optimize(rules=("R1R2", "R3"))
        kw = dict(
            network=NetworkModel.preset("two-rack"),
            sizes=SizeModel(default_bytes=1 << 19),
            costs=CostModel(default_exec_s=1e-3),
            exec_slots=1,
        )
        before = simulate(raw.system, **kw)
        after = simulate(opt.system, **kw)
        assert after.cross_bytes <= before.cross_bytes
        assert after.makespan <= before.makespan + 1e-9


# ---------------------------------------------------------------------------
# Placement search
# ---------------------------------------------------------------------------


class TestPlacement:
    def test_round_robin_is_deterministic_and_pins_spatial(self):
        inst = TrainPipelineTranslator(n_pods=2).instance()
        rr = round_robin_placement(inst)
        assert rr == round_robin_placement(inst)
        # the gradsync collective keeps its multi-location mapping
        assert set(rr["gradsync"]) == set(inst.locs_of("gradsync"))

    def test_greedy_bytes_objective_colocates_a_chain(self):
        edges = {"a": ["b"], "b": ["c"], "c": []}
        mapping = {"a": ("l0",), "b": ("l1",), "c": ("l0",)}
        inst = swirl.trace(edges, mapping=mapping).instance
        placed = greedy_placement(
            inst,
            NetworkModel.preset("uniform"),
            sizes=SizeModel(default_bytes=1 << 20),
            costs=CostModel(default_exec_s=1e-6),
            objective="bytes",
        )
        # with huge payloads and negligible exec cost the chain collapses
        locs = {placed[s] for s in ("a", "b", "c")}
        assert len(locs) == 1

    def test_auto_placement_reports_against_round_robin(self):
        inst = genomes_1000(n=2, m=2, a=1, b=1, c=1)
        report = auto_placement(
            inst,
            NetworkModel.preset("two-rack"),
            sizes=SizeModel(default_bytes=1 << 18),
            costs=CostModel(default_exec_s=1e-3),
        )
        assert isinstance(report, ScheduleReport)
        assert set(report.placement) == set(inst.workflow.steps)
        assert report.predicted.cross_bytes <= report.baseline.cross_bytes
        assert report.search_seconds > 0
        assert "placement" in report.summary()

    def test_bad_objective_rejected(self):
        inst = genomes_1000(n=2, m=2, a=1, b=1, c=1)
        with pytest.raises(ValueError, match="objective"):
            auto_placement(inst, objective="latency")


# ---------------------------------------------------------------------------
# Plan.schedule / placement="auto" integration
# ---------------------------------------------------------------------------


class TestPlanSchedule:
    def test_requires_front_end_instance(self):
        from repro.core.parser import dumps

        plan = swirl.trace(EDGES, mapping=MAPPING)
        text_plan = swirl.trace(dumps(plan.system))
        with pytest.raises(ValueError, match="front-end instance"):
            text_plan.schedule()

    def test_schedule_attaches_report_and_explains(self):
        plan = swirl.trace(EDGES, mapping=MAPPING).optimize()
        sched = plan.schedule(NetworkModel.preset("two-rack"))
        assert sched.schedule_report is not None
        assert "-- schedule --" in sched.explain()
        assert "predicted makespan" in sched.explain()

    def test_schedule_reruns_the_optimiser(self):
        plan = swirl.trace(EDGES, mapping=MAPPING).optimize(
            rules=("R1R2", "R3")
        )
        sched = plan.schedule()
        assert [r.rule for r in sched.rewrites] == ["R1R2", "R3"]
        # a never-optimised plan gets the paper's default rule set, so the
        # lowered system matches what the schedule report scored
        unopt = swirl.trace(EDGES, mapping=MAPPING).schedule()
        assert [r.rule for r in unopt.rewrites] == ["R1R2"]
        assert (
            simulate(
                unopt.system, network=unopt.schedule_report.network,
                exec_slots=1,
            ).cross_bytes
            == unopt.schedule_report.predicted.cross_bytes
        )

    def test_schedule_respects_pin_and_spatial_constraints(self):
        plan = swirl.trace(TrainPipelineTranslator(n_pods=2))
        sched = plan.schedule(pin=("shard_0",))
        assert sched.placement()["shard_0"] == plan.placement()["shard_0"]
        assert set(sched.placement()["gradsync"]) == {"pod0", "pod1"}

    def test_schedule_scores_with_recorded_r3(self):
        """A plan optimised with R3 is searched and reported under R3 too:
        the report's prediction matches a fresh simulation of the lowered
        system."""
        plan = swirl.trace(TrainPipelineTranslator(n_pods=4)).optimize(
            rules=("R1R2", "R3")
        )
        sizes = SizeModel(default_bytes=1 << 20)
        sched = plan.schedule(
            NetworkModel.preset("two-rack"), sizes=sizes
        )
        report = sched.schedule_report
        fresh = simulate(
            sched.system,
            network=report.network,
            sizes=sizes,
            exec_slots=1,
        )
        assert fresh.cross_bytes == report.predicted.cross_bytes
        assert fresh.makespan == pytest.approx(report.predicted.makespan)

    def test_steps_registry_feeds_the_estimators(self):
        metas = {
            name: StepMeta(
                fn=fn, expected_seconds=0.01, output_bytes={f"d^{name}": 64}
            )
            for name, fn in quickstart_steps().items()
        }
        plan = swirl.trace(EDGES, mapping=MAPPING).optimize()
        sched = plan.schedule(steps=metas)
        assert sched.schedule_report.predicted.exec_seconds == pytest.approx(
            0.05
        )

    def test_lower_auto_runs_scheduler(self):
        plan = swirl.trace(EDGES, mapping=MAPPING).optimize()
        lowered = plan.lower(
            "inprocess",
            placement="auto",
            network=NetworkModel.preset("two-rack"),
        )
        assert lowered.plan.schedule_report is not None
        assert lowered.options["schedule"] is lowered.plan.schedule_report
        result = lowered.compile(quickstart_steps()).run()
        assert result.payload(
            lowered.plan.placement()["evaluate"][0], "d^evaluate"
        ) == 54

    def test_lower_rejects_bad_placement_string_and_stray_network(self):
        plan = swirl.trace(EDGES, mapping=MAPPING)
        with pytest.raises(ValueError, match="auto"):
            plan.lower("inprocess", placement="automatic")
        with pytest.raises(TypeError, match="network"):
            plan.lower("inprocess", network=NetworkModel.preset("uniform"))
        with pytest.raises(TypeError, match="objective"):
            plan.lower(
                "inprocess",
                placement={"evaluate": ("gpu1",)},
                objective="bytes",
            )

    def test_schedule_handdown_skips_unaware_backends(self):
        """A third-party backend whose known_options() predates the
        scheduler (no super() call) must still lower scheduled plans."""
        from repro import backends as backend_registry
        from repro.backends import Backend, get_backend, register_backend

        class LegacyBackend(Backend):
            name = "legacy"

            def known_options(self):
                return frozenset({"devices"})  # PR-1 style: no super()

            def compile(self, system, steps, options):
                assert "schedule" not in options
                return get_backend("inprocess").compile(
                    system, steps, options
                )

        register_backend(
            "legacy-test", lambda: LegacyBackend(), overwrite=True
        )
        try:
            sched = swirl.trace(EDGES, mapping=MAPPING).schedule()
            result = (
                sched.lower("legacy-test")
                .compile(quickstart_steps())
                .run()
            )
            assert result.payload(
                sched.placement()["evaluate"][0], "d^evaluate"
            ) == 54
        finally:
            backend_registry._REGISTRY.pop("legacy-test", None)

    def test_schedule_option_accepted_by_every_backend(self):
        plan = swirl.trace(EDGES, mapping=MAPPING).optimize().schedule()
        for backend in ("inprocess", "threaded", "jax"):
            result = (
                plan.lower(backend)
                .compile(quickstart_steps())
                .run()
            )
            assert result.backend == backend

    def test_jax_device_map_groups_rack_members(self):
        plan = swirl.trace(EDGES, mapping=MAPPING).optimize()
        sched = plan.schedule(
            NetworkModel.preset(
                "two-rack",
                racks={"rack0": ["cpu0", "gpu0"], "rack1": ["gpu1"]},
            )
        )
        # fake device objects: the program only str()s them for non-arrays
        exe = sched.lower("jax", devices=["devA", "devB"]).compile(
            quickstart_steps()
        )
        devices = exe.run().stats["devices"]
        assert devices["cpu0"] == devices["gpu0"] == "devA"
        assert devices["gpu1"] == "devB"


# ---------------------------------------------------------------------------
# Acceptance criteria
# ---------------------------------------------------------------------------


GENOMES_SIZES = SizeModel(default_bytes=8 * 65536)  # 64k-float arrays
GENOMES_COSTS = CostModel(default_exec_s=5e-3)


class TestAcceptance:
    def test_genomes_two_rack_saves_30_percent_bytes(self):
        """placement="auto" moves ≥30% fewer cross-location bytes than
        round-robin on 1000 Genomes under the two-rack preset."""
        inst = genomes_1000(n=4, m=3, a=2, b=2, c=2)
        plan = swirl.trace(inst).optimize()
        sched = plan.schedule(
            NetworkModel.preset("two-rack"),
            sizes=GENOMES_SIZES,
            costs=GENOMES_COSTS,
        )
        report = sched.schedule_report
        assert report.baseline.cross_bytes > 0
        assert report.bytes_saved_frac >= 0.30

    @pytest.mark.slow
    def test_simulated_ordering_matches_threaded_wall_clock(self):
        """The simulator's makespan ordering (auto vs round-robin) agrees
        with measured wall-clock on the threaded backend.

        Wall-clock is noisy, so this only asserts the *direction* with a
        generous margin: the scheduler must predict an improvement of at
        least 20%, and the measured scheduled run must then beat the
        round-robin run with a 10% noise allowance — not the knife-edge
        ``auto < rr`` ordering this test used to flake on.
        """
        inst = genomes_1000(n=2, m=2, a=1, b=1, c=1)
        delay = 0.03
        network = NetworkModel.preset(
            "uniform", bandwidth=float("inf"), latency=delay
        )
        costs = CostModel(default_exec_s=1e-3)
        plan = swirl.trace(inst).optimize()
        sched = plan.schedule(network, costs=costs)
        report = sched.schedule_report

        def fns():
            out = {}
            for s in inst.workflow.steps:
                outs = inst.out_data(s)

                def fn(inputs, outs=outs):
                    time.sleep(1e-3)
                    return {o: sum(map(len, inputs)) for o in outs}

                out[s] = fn
            return out

        init = {("l^d", d): "x" for d in inst.g("l^d")}

        def wall(p):
            t0 = time.perf_counter()
            (
                p.lower("threaded", delay_s=delay, timeout_s=60)
                .compile(fns())
                .run(initial_payloads=dict(init))
            )
            return time.perf_counter() - t0

        wall_auto = wall(sched)
        wall_rr = wall(
            plan.lower("threaded", placement=dict(report.baseline_placement))
            .plan  # noqa: SLF001 — re-placed plan, same rewrites
        )
        # The 30ms-per-hop delay dominates step time (1ms), so a predicted
        # improvement below this margin would make the wall-clock
        # comparison a coin flip — the fixture is then wrong, not timing.
        assert report.predicted.makespan < 0.8 * report.baseline.makespan, (
            f"scheduler did not predict a solid improvement: "
            f"{report.predicted.makespan} vs {report.baseline.makespan}"
        )
        assert wall_auto < wall_rr * 1.1, (
            f"scheduled run not measurably faster: "
            f"predicted {report.predicted.makespan:.4f}s vs rr "
            f"{report.baseline.makespan:.4f}s, measured "
            f"{wall_auto:.4f}s vs rr {wall_rr:.4f}s"
        )

    def test_scheduled_plan_preserves_behaviour_everywhere(self):
        """Scheduling preserves the bisimulation certificate and produces
        identical results on every registered backend."""
        from repro.backends import available_backends

        plan = swirl.trace(EDGES, mapping=MAPPING).optimize()
        sched = plan.schedule(
            NetworkModel.preset("two-rack")
        ).certify()
        assert sched.certificate is not None
        assert sched.certificate.equivalent

        results = {
            b: sched.lower(b).compile(quickstart_steps()).run()
            for b in available_backends()
        }
        datas = list(r.data for r in results.values())
        assert all(d == datas[0] for d in datas[1:])
