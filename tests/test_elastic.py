"""Elasticity: renaming invariance, recovery, rebalance."""

import random

from repro.core import encode, optimize, run
from repro.core.translate import genomes_1000
from repro.workflow import (
    Checkpoint,
    Runtime,
    plan_recovery,
    rebalance,
    recover_checkpoint,
    rename_locations,
)

from conftest import identity_step_fns


def _setup(n=3, m=2):
    inst = genomes_1000(n=n, m=m, a=2, b=2, c=2)
    w, _ = optimize(encode(inst))
    fns = identity_step_fns(inst)
    init = {("l^d", d): f"raw:{d}" for d in inst.g("l^d")}
    return inst, w, fns, init


def test_rename_is_semantics_invariant():
    inst, w, fns, init = _setup()
    ren = {"l^MO_1": "spare1", "l^F_2": "spare2"}
    w2 = rename_locations(w, ren)
    init2 = {(ren.get(l, l), d): v for (l, d), v in init.items()}
    r1 = run(w, rng=random.Random(3))
    r2 = run(w2, rng=random.Random(3))
    assert not r1.deadlocked and not r2.deadlocked
    assert len(r1.exec_events) == len(r2.exec_events)
    rt = Runtime(w2, fns, initial_payloads=init2)
    rt.run()
    assert "d^IM" in rt.location_data("spare1")


def test_scale_down_merges_locations():
    inst, w, fns, init = _setup()
    # fold both MO locations onto one
    w2 = rename_locations(w, {"l^MO_2": "l^MO_1"})
    assert "l^MO_2" not in w2.locations()
    rt = Runtime(w2, fns, initial_payloads=init)
    stats = rt.run()
    assert stats.execs == len(inst.workflow.steps)


def test_recovery_from_checkpoint(tmp_path):
    inst, w, fns, init = _setup(n=4, m=3)
    path = tmp_path / "wf.ckpt"
    rt = Runtime(w, fns, initial_payloads=init, checkpoint_every=3,
                 checkpoint_path=path)
    rt.run()
    ckpt = Checkpoint.load(path)

    # l^MO_1 "dies"; plan a substitution and resume
    ren = plan_recovery(
        live=[l for l in w.locations() if l != "l^MO_1"],
        dead=["l^MO_1"],
        spares=["l^spare"],
    )
    assert ren == {"l^MO_1": "l^spare"}
    ckpt2 = recover_checkpoint(ckpt, ren)
    rt2 = Runtime.restore(ckpt2, fns)
    rt2.run()
    assert "d^IM" in rt2.location_data("l^spare")


def test_plan_recovery_folds_without_spares():
    ren = plan_recovery(live=["a", "b"], dead=["x", "y", "z"], spares=["s1"])
    assert ren["x"] == "s1"
    assert set(ren.values()) <= {"s1", "a", "b"}


def test_rebalance_reencodes():
    inst, w, fns, init = _setup()
    # move every MO/F step onto a single fat node
    new_mapping = {
        s: (("fat",) if s.startswith(("sMO", "sF")) else inst.locs_of(s))
        for s in inst.workflow.steps
    }
    w2 = rebalance(inst, new_mapping)
    assert "fat" in w2.locations()
    rt = Runtime(w2, fns, initial_payloads=init)
    stats = rt.run()
    assert stats.execs == len(inst.workflow.steps)
    assert "d^IM" in rt.location_data("fat")
