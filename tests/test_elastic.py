"""Elasticity: renaming invariance, recovery, rebalance.

Two layers are covered, and checked against each other:

* the *tree* layer (:mod:`repro.workflow.elastic`) — rename the
  checkpointed term, re-encode, resume — kept as the semantics oracle;
* the *exec-IR* layer (:mod:`repro.exec.elastic`) — the same substitution
  applied directly to the lowered op arrays, which is what the live
  multiprocess recovery path uses.

``rename_program(lower(w), ren).system`` must agree with
``rename_locations(w, ren)`` exactly (on spatial-free instances — a fold
that collapses a spatial step diverges deliberately, by dropping the
now-redundant synchronised copies), and the renamed program must *execute*
to the clean run's data modulo the renaming.
"""

import random

import pytest

from repro import swirl
from repro.backends import get_backend
from repro.core import encode, optimize, run
from repro.core.compile import StepMeta
from repro.core.parser import dumps
from repro.core.randgen import random_layered_instance
from repro.core.translate import genomes_1000
from repro.exec import lower_system, rename_program
from repro.workflow import (
    Checkpoint,
    Runtime,
    fold_payloads,
    plan_recovery,
    rebalance,
    recover_checkpoint,
    rename_locations,
)

from conftest import identity_step_fns


def _setup(n=3, m=2):
    inst = genomes_1000(n=n, m=m, a=2, b=2, c=2)
    w, _ = optimize(encode(inst))
    fns = identity_step_fns(inst)
    init = {("l^d", d): f"raw:{d}" for d in inst.g("l^d")}
    return inst, w, fns, init


def test_rename_is_semantics_invariant():
    inst, w, fns, init = _setup()
    ren = {"l^MO_1": "spare1", "l^F_2": "spare2"}
    w2 = rename_locations(w, ren)
    init2 = {(ren.get(l, l), d): v for (l, d), v in init.items()}
    r1 = run(w, rng=random.Random(3))
    r2 = run(w2, rng=random.Random(3))
    assert not r1.deadlocked and not r2.deadlocked
    assert len(r1.exec_events) == len(r2.exec_events)
    rt = Runtime(w2, fns, initial_payloads=init2)
    rt.run()
    assert "d^IM" in rt.location_data("spare1")


def test_scale_down_merges_locations():
    inst, w, fns, init = _setup()
    # fold both MO locations onto one
    w2 = rename_locations(w, {"l^MO_2": "l^MO_1"})
    assert "l^MO_2" not in w2.locations()
    rt = Runtime(w2, fns, initial_payloads=init)
    stats = rt.run()
    assert stats.execs == len(inst.workflow.steps)


def test_recovery_from_checkpoint(tmp_path):
    inst, w, fns, init = _setup(n=4, m=3)
    path = tmp_path / "wf.ckpt"
    rt = Runtime(w, fns, initial_payloads=init, checkpoint_every=3,
                 checkpoint_path=path)
    rt.run()
    ckpt = Checkpoint.load(path)

    # l^MO_1 "dies"; plan a substitution and resume
    ren = plan_recovery(
        live=[l for l in w.locations() if l != "l^MO_1"],
        dead=["l^MO_1"],
        spares=["l^spare"],
    )
    assert ren == {"l^MO_1": "l^spare"}
    ckpt2 = recover_checkpoint(ckpt, ren)
    rt2 = Runtime.restore(ckpt2, fns)
    rt2.run()
    assert "d^IM" in rt2.location_data("l^spare")


def test_plan_recovery_folds_without_spares():
    ren = plan_recovery(live=["a", "b"], dead=["x", "y", "z"], spares=["s1"])
    assert ren["x"] == "s1"
    assert set(ren.values()) <= {"s1", "a", "b"}


def test_plan_recovery_round_robin_starts_at_first_live():
    # Regression: the fold round-robin used to be indexed by the *overall*
    # dead position, so deads that consumed spares skewed every later fold
    # assignment.  It must index from the first *folded* entry.
    ren = plan_recovery(live=["a", "b"], dead=["x", "y"], spares=["s1"])
    assert ren == {"x": "s1", "y": "a"}


def test_plan_recovery_fold_balances_after_spare_exhaustion():
    ren = plan_recovery(
        live=["a", "b"], dead=["v", "w", "x", "y", "z"], spares=["s1"]
    )
    assert ren == {"v": "s1", "w": "a", "x": "b", "y": "a", "z": "b"}


def test_plan_recovery_without_any_target_raises():
    with pytest.raises(RuntimeError):
        plan_recovery(live=[], dead=["x"], spares=[])


def test_fold_payloads_survivor_beats_dead_and_dead_ties_break_low():
    # Regression: the fold used to keep whichever payload dict iteration
    # visited last.  The precedence is fixed: a survivor's copy of a datum
    # always wins over one inherited from a renamed (dead) location, and
    # between dead sources the lexicographically smallest wins.
    ren = {"dead_a": "live", "dead_b": "live"}
    folded = fold_payloads(
        {
            ("dead_b", "d"): "from_b",
            ("live", "d"): "mine",
            ("dead_a", "d"): "from_a",
            ("dead_b", "e"): "only_b",
        },
        ren,
    )
    assert folded == {("live", "d"): "mine", ("live", "e"): "only_b"}
    no_survivor = fold_payloads(
        {("dead_b", "d"): "from_b", ("dead_a", "d"): "from_a"}, ren
    )
    assert no_survivor == {("live", "d"): "from_a"}


def test_recover_checkpoint_folds_payloads_deterministically():
    inst, w, fns, init = _setup()
    ckpt = Checkpoint(
        system_text=dumps(w),
        payloads={
            ("l^MO_1", "d^x"): "from_mo1",
            ("l^MO_2", "d^x"): "from_mo2",
            ("l^F_1", "d^x"): "survivor",
        },
        completed_execs=frozenset({"sIM"}),
    )
    ckpt2 = recover_checkpoint(
        ckpt, {"l^MO_1": "l^F_1", "l^MO_2": "l^F_1"}
    )
    assert ckpt2.payloads == {("l^F_1", "d^x"): "survivor"}
    assert ckpt2.completed_execs == frozenset({"sIM"})
    assert "l^MO_1" not in ckpt2.system.locations()


def test_recover_checkpoint_round_trips_through_disk(tmp_path):
    inst, w, fns, init = _setup()
    path = tmp_path / "wf.ckpt"
    rt = Runtime(w, fns, initial_payloads=init, checkpoint_every=3,
                 checkpoint_path=path)
    rt.run()
    ckpt2 = recover_checkpoint(Checkpoint.load(path), {"l^MO_1": "l^spare"})
    out = tmp_path / "recovered.ckpt"
    ckpt2.save(out)
    loaded = Checkpoint.load(out)
    assert loaded.payloads == ckpt2.payloads
    assert loaded.completed_execs == ckpt2.completed_execs
    assert loaded.system == ckpt2.system
    assert "l^spare" in loaded.system.locations()


# ---------------------------------------------------------------------------
# Exec-IR renaming (repro.exec.elastic) vs the tree oracle
# ---------------------------------------------------------------------------


def _random_plan(seed, n_steps=12, p_spatial=0.0):
    inst = random_layered_instance(
        n_steps, n_locations=4, seed=seed, p_spatial=p_spatial
    )
    return inst, swirl.trace(inst).optimize()


def test_rename_program_bijective_matches_tree_oracle():
    for seed in range(10):
        inst, plan = _random_plan(seed)
        w = plan.system
        locs = sorted(w.locations())
        ren = {l: f"spare{i}" for i, l in enumerate(locs[:2])}
        arrays = rename_program(lower_system(w), ren).system
        tree = rename_locations(w, ren)
        assert arrays == tree, f"seed {seed} diverged from the oracle"


def test_rename_program_surjective_matches_tree_oracle():
    for seed in range(10):
        inst, plan = _random_plan(seed)
        w = plan.system
        locs = sorted(w.locations())
        if len(locs) < 2:
            continue
        # Fold the two smallest locations onto the largest (scale-down).
        ren = {l: locs[-1] for l in locs[:2]}
        arrays = rename_program(lower_system(w), ren).system
        tree = rename_locations(w, ren)
        assert arrays == tree, f"seed {seed} diverged from the oracle"


def _run_renamed(plan, fns, ren):
    """Execute the renamed op arrays directly through a backend."""
    renamed = rename_program(lower_system(plan.system), ren)
    metas = {s: StepMeta(fn=fn) for s, fn in fns.items()}
    exe = get_backend("threaded").compile(renamed, metas, {"timeout_s": 60})
    return exe.run().data


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_renamed_program_executes_equivalently_bijective(seed):
    inst, plan = _random_plan(seed, n_steps=10)
    fns = identity_step_fns(inst)
    clean = plan.lower("threaded", timeout_s=60).compile(fns).run().data
    locs = sorted(plan.system.locations())
    ren = {locs[0]: "spare0"}
    data = _run_renamed(plan, fns, ren)
    assert data == {ren.get(l, l): d for l, d in clean.items()}


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_folded_program_executes_equivalently(seed):
    inst, plan = _random_plan(seed, n_steps=10)
    fns = identity_step_fns(inst)
    clean = plan.lower("threaded", timeout_s=60).compile(fns).run().data
    locs = sorted(plan.system.locations())
    if len(locs) < 2:
        pytest.skip("optimised plan collapsed to one location")
    ren = {locs[0]: locs[-1]}
    data = _run_renamed(plan, fns, ren)
    expected: dict = {}
    for l, d in clean.items():
        expected.setdefault(ren.get(l, l), {}).update(d)
    assert data == expected


def test_folded_spatial_step_executes_once_per_location_set():
    # A fold that collapses both members of a spatial M(s) onto one name
    # deliberately diverges from the tree oracle: the synchronised copies
    # become redundant and all but the first are dropped.  The executed
    # *data* must still match the clean run.
    for seed in range(6):
        inst, plan = _random_plan(seed, n_steps=10, p_spatial=0.5)
        fns = identity_step_fns(inst)
        clean = plan.lower("threaded", timeout_s=60).compile(fns).run().data
        locs = sorted(plan.system.locations())
        if len(locs) < 2:
            continue
        ren = {locs[0]: locs[1]}
        data = _run_renamed(plan, fns, ren)
        expected: dict = {}
        for l, d in clean.items():
            expected.setdefault(ren.get(l, l), {}).update(d)
        assert data == expected, f"seed {seed} diverged after spatial fold"


def test_rebalance_reencodes():
    inst, w, fns, init = _setup()
    # move every MO/F step onto a single fat node
    new_mapping = {
        s: (("fat",) if s.startswith(("sMO", "sF")) else inst.locs_of(s))
        for s in inst.workflow.steps
    }
    w2 = rebalance(inst, new_mapping)
    assert "fat" in w2.locations()
    rt = Runtime(w2, fns, initial_payloads=init)
    stats = rt.run()
    assert stats.execs == len(inst.workflow.steps)
    assert "d^IM" in rt.location_data("fat")
