"""Flat indexed trace IR: round-trip and flat-vs-tree engine equivalence.

The flat engines (``repro.core.flat``) are the production rewriting path;
the recursive tree walkers are kept as the reference oracle.  This suite
pins the contract:

* ``tree → flat → tree`` is the identity (raw node-for-node) while nothing
  is deleted, and ``encode_flat(I).to_system() == encode(I)`` exactly;
* the flat R1R2/R3 engines produce systems **equal** to the reference
  engines with **identical** ``OptimizationStats`` — on a seeded sweep of
  random layered DAGs, on the named workloads, and under hypothesis (which
  additionally shrinks failures);
* the R3 stats account one removed predicate per side: the send at its
  source location and the recv at its destination (the historical
  accounting bumped only the source, and only once per pair).
"""

from __future__ import annotations

import random

import pytest
from conftest import given, instances, settings

from repro.core import (
    encode,
    encode_flat,
    rewrite_flat_pipeline,
    rewrite_spatial,
    rewrite_spatial_tree,
    rewrite_system,
    rewrite_system_tree,
)
from repro.core.flat import FlatSystem, FlatTrace
from repro.core.parser import parse_system
from repro.core.randgen import random_layered_instance
from repro.core.syntax import (
    NIL,
    Exec,
    Nil,
    Par,
    Recv,
    Send,
    Seq,
    config,
    par,
    seq,
    system,
)
from repro.core.translate import TrainPipelineTranslator, genomes_1000
from test_differential import random_instance

N_SEEDS = 60


def _assert_engines_agree(w, *, rules=("R1R2", "R3")):
    """Flat and tree engines must return equal systems and equal stats."""
    sys_t = w
    stats_t = []
    tree = {"R1R2": rewrite_system_tree, "R3": rewrite_spatial_tree}
    for rule in rules:
        sys_t, st = tree[rule](sys_t)
        stats_t.append(st)
    sys_f = w
    stats_f = []
    flat = {"R1R2": rewrite_system, "R3": rewrite_spatial}
    for rule in rules:
        sys_f, sf = flat[rule](sys_f)
        stats_f.append(sf)
    assert sys_f == sys_t
    assert stats_f == stats_t
    # The single-flatten pipeline must agree with rule-at-a-time rewriting.
    pipe_sys, pipe_stats = rewrite_flat_pipeline(w, tuple(rules))
    assert pipe_sys == sys_t
    assert pipe_stats == stats_t


# ---------------------------------------------------------------------------
# Round-trip
# ---------------------------------------------------------------------------


class TestRoundTrip:
    def test_exact_identity_on_handcrafted_trees(self):
        ex = Exec("s", frozenset({"a"}), frozenset({"b"}), ("l",))
        cases = [
            NIL,
            ex,
            seq(Recv("p", "l1", "l"), ex, Send("b", "q", "l", "l2")),
            par(seq(ex, ex), Recv("p", "l1", "l")),
            # raw (non-smart-constructor) shapes must survive verbatim
            Seq((Nil(), ex, Par((ex, Nil())))),
        ]
        for t in cases:
            assert FlatTrace.from_trace(t).to_trace() == t

    def test_exact_identity_on_random_encoded_systems(self):
        for seed in range(N_SEEDS):
            w = encode(random_instance(random.Random(seed)))
            assert FlatSystem.from_system(w).to_system() == w

    def test_to_trace_refuses_after_deletion(self):
        ft = FlatTrace.from_trace(seq(Recv("p", "a", "a"), Recv("q", "b", "a")))
        ft.alive[0] = False
        with pytest.raises(ValueError, match="deleted"):
            ft.to_trace()
        assert ft.rebuild() == Recv("q", "b", "a")

    def test_encode_flat_matches_encode(self):
        for seed in range(N_SEEDS):
            inst = random_instance(random.Random(seed))
            assert encode_flat(inst).to_system() == encode(inst)
        for inst in (
            genomes_1000(n=4, m=3, a=2, b=2, c=2),
            TrainPipelineTranslator(n_pods=3).instance(),
            random_layered_instance(300, n_locations=4, seed=7, p_spatial=0.3),
        ):
            assert encode_flat(inst).to_system() == encode(inst)


# ---------------------------------------------------------------------------
# Differential: flat engines vs recursive reference engines
# ---------------------------------------------------------------------------


class TestSeededDifferential:
    @pytest.mark.parametrize("chunk", range(6))
    def test_random_dags(self, chunk):
        for i in range(N_SEEDS // 6):
            rng = random.Random(97 * chunk + i)
            w = encode(random_instance(rng))
            _assert_engines_agree(w, rules=("R1R2",))
            _assert_engines_agree(w, rules=("R1R2", "R3"))
            _assert_engines_agree(w, rules=("R3",))

    def test_named_workloads(self):
        for inst in (
            genomes_1000(n=4, m=3, a=2, b=2, c=2),
            TrainPipelineTranslator(n_pods=3).instance(),
        ):
            _assert_engines_agree(encode(inst))

    def test_large_layered_dag(self):
        inst = random_layered_instance(400, n_locations=4, seed=3, p_spatial=0.4)
        _assert_engines_agree(encode(inst))

    def test_flat_rewrite_idempotent(self):
        w = encode(genomes_1000(n=4, m=3, a=2, b=2, c=2))
        o1, s1 = rewrite_system(w)
        o2, s2 = rewrite_system(o1)
        assert o1 == o2
        assert s2.removed == 0

    def test_parsed_system(self):
        w = parse_system(
            "<l,{},recv(p,l1,l).exec(s,{d}->{d1},{l})."
            "(send(d1->p1,l,lp) | send(d1->p1,l,lp))>"
            " | <lp,{},recv(p1,l,lp).exec(s1,{d1}->{},{lp})"
            " | recv(p1,l,lp).exec(s2,{d1}->{},{lp})>"
        )
        _assert_engines_agree(w, rules=("R1R2",))


class TestHypothesisDifferential:
    @given(inst=instances(max_layers=4, max_width=3, max_locations=3))
    @settings(max_examples=30, deadline=None)
    def test_engines_agree(self, inst):
        w = encode(inst)
        _assert_engines_agree(w, rules=("R1R2",))
        _assert_engines_agree(w, rules=("R1R2", "R3"))

    @given(inst=instances(max_layers=3, max_width=3, max_locations=4))
    @settings(max_examples=25, deadline=None)
    def test_round_trip(self, inst):
        w = encode(inst)
        assert FlatSystem.from_system(w).to_system() == w
        assert encode_flat(inst).to_system() == w


# ---------------------------------------------------------------------------
# R3 stats accounting (satellite fix)
# ---------------------------------------------------------------------------


class TestR3StatsAccounting:
    def _spatial_pair_system(self):
        """s runs jointly on a and b; each re-broadcasts its output to the
        other — both send/recv pairs are R3-redundant."""
        return parse_system(
            "<a,{x},exec(s,{x}->{d},{a,b}).send(d->p,a,b)"
            " | recv(p,b,a).exec(t,{d}->{},{a})>"
            " | <b,{x},exec(s,{x}->{d},{a,b}).send(d->p,b,a)"
            " | recv(p,a,b).exec(u,{d}->{},{b})>"
        )

    @pytest.mark.parametrize(
        "engine", [rewrite_spatial, rewrite_spatial_tree]
    )
    def test_counts_send_at_src_and_recv_at_dst(self, engine):
        o, stats = engine(self._spatial_pair_system())
        assert o.comm_count() == 0
        # Two pairs removed: a→b and b→a.  Each pair is one send predicate
        # at its source plus one recv predicate at its destination.
        assert stats.removed_duplicate == 4
        assert stats.by_location == {"a": 2, "b": 2}

    def test_by_location_total_matches_removed(self):
        for seed in range(20):
            w = encode(
                random_layered_instance(
                    60, n_locations=3, seed=seed, p_spatial=0.4
                )
            )
            o, _ = rewrite_system(w)
            _, stats = rewrite_spatial(o)
            assert sum(stats.by_location.values()) == stats.removed
