"""The ``multiprocess`` backend: real OS processes, typed failure, resume.

Covers the ISSUE-3 acceptance criteria: distinct PIDs per location group,
results identical to the other backends (including on the 1000 Genomes
workflow), no leaked worker processes after success *or* failure, a killed
worker surfacing as :class:`WorkerFailedError` naming the right location
and step, and checkpoint/restore resuming to the same result without
re-executing completed steps.
"""

from __future__ import annotations

import glob
import multiprocessing as mp
import os
import signal

import numpy as np
import pytest

from repro import swirl
from repro.backends import WorkerFailedError, available_backends, get_backend
from repro.backends.multiprocess import assign_workers
from repro.core.translate import genomes_1000

EDGES = {
    "preprocess": ["train_a", "train_b"],
    "train_a": ["evaluate"],
    "train_b": ["evaluate"],
    "evaluate": ["report"],
    "report": [],
}
MAPPING = {
    "preprocess": ("cpu0",),
    "train_a": ("gpu0",),
    "train_b": ("gpu1",),
    "evaluate": ("gpu0",),
    "report": ("cpu0",),
}


def quickstart_steps():
    return {
        "preprocess": lambda inp: {"d^preprocess": list(range(10))},
        "train_a": lambda inp: {"d^train_a": sum(inp["d^preprocess"])},
        "train_b": lambda inp: {"d^train_b": max(inp["d^preprocess"])},
        "evaluate": lambda inp: {
            "d^evaluate": inp["d^train_a"] + inp["d^train_b"]
        },
        "report": lambda inp: {},
    }


@pytest.fixture
def plan():
    return swirl.trace(EDGES, mapping=MAPPING).optimize()


def _pid_gone(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return True
    except PermissionError:  # pragma: no cover - alive but not ours
        return False
    return False


def _assert_no_workers_left(program) -> None:
    assert not mp.active_children(), "worker processes were not reaped"
    assert program.last_pids, "run never recorded its worker pids"
    leaked = [pid for pid in program.last_pids.values() if not _pid_gone(pid)]
    if leaked:  # pragma: no cover - best-effort second opinion
        try:
            import psutil

            leaked = [
                p for p in leaked if psutil.pid_exists(p)
            ]
        except ModuleNotFoundError:
            pass
    assert not leaked, f"orphan worker processes: {leaked}"


# ---------------------------------------------------------------------------
# Real processes, correct results
# ---------------------------------------------------------------------------


class TestProcessIsolation:
    def test_registered_with_checkpoint_capability(self):
        b = get_backend("multiprocess")
        assert "multiprocess" in available_backends()
        assert "checkpoint" in b.capabilities

    def test_each_location_group_is_a_distinct_os_process(self, plan):
        exe = plan.lower("multiprocess").compile(quickstart_steps())
        result = exe.run()
        pids = result.stats["pids"]
        assert len(pids) == result.stats["workers"] == 3
        assert len(set(pids.values())) == 3, "workers shared a process"
        assert os.getpid() not in pids.values(), "a worker ran in-process"
        _assert_no_workers_left(exe.program)

    def test_identical_to_every_other_backend(self, plan):
        results = {
            b: plan.lower(b).compile(quickstart_steps()).run().data
            for b in available_backends()
        }
        reference = results.pop("multiprocess")
        for backend, data in results.items():
            assert data == reference, f"{backend} diverged from multiprocess"

    def test_identical_on_1000_genomes(self):
        inst = genomes_1000(n=2, m=2, a=1, b=1, c=1)
        plan = swirl.trace(inst).optimize()
        fns = {}
        for s in inst.workflow.steps:
            outs = inst.out_data(s)
            fns[s] = lambda i, s=s, outs=outs: {
                o: f"{s}({','.join(sorted(map(str, i)))})" for o in outs
            }
        init = {("l^d", d): f"raw:{d}" for d in inst.g("l^d")}
        results = {
            b: plan.lower(b, **({"timeout_s": 60} if b in ("threaded", "multiprocess") else {}))
            .compile(fns)
            .run(initial_payloads=dict(init))
            .data
            for b in available_backends()
        }
        reference = results.pop("multiprocess")
        for backend, data in results.items():
            assert data == reference, f"{backend} diverged on 1000 Genomes"

    def test_initial_payloads_reach_their_worker(self, plan):
        init = {("cpu0", "seed"): [5, 6, 7]}
        result = (
            plan.lower("multiprocess")
            .compile(quickstart_steps())
            .run(initial_payloads=dict(init))
        )
        threaded = (
            plan.lower("threaded")
            .compile(quickstart_steps())
            .run(initial_payloads=dict(init))
        )
        assert result.payload("cpu0", "seed") == [5, 6, 7]
        assert result.data == threaded.data


# ---------------------------------------------------------------------------
# Worker assignment: spatial constraints, workers=, schedule pinning
# ---------------------------------------------------------------------------


class TestWorkerAssignment:
    def test_default_one_process_per_location(self, plan):
        groups = assign_workers(plan.system)
        assert groups == [("cpu0",), ("gpu0",), ("gpu1",)]

    def test_spatial_constraint_locations_share_a_process(self):
        mapping = dict(MAPPING, evaluate=("gpu0", "gpu1"))
        plan = swirl.trace(EDGES, mapping=mapping).optimize()
        groups = assign_workers(plan.system)
        assert ("gpu0", "gpu1") in groups
        result = plan.lower("multiprocess").compile(quickstart_steps()).run()
        assert result.payload("cpu0", "d^evaluate") == 54
        assert result.stats["workers"] == 2

    def test_workers_option_packs_groups(self, plan):
        result = (
            plan.lower("multiprocess", workers=2)
            .compile(quickstart_steps())
            .run()
        )
        assert result.stats["workers"] == 2
        assert len(set(result.stats["pids"].values())) == 2
        assert result.payload("cpu0", "d^evaluate") == 54

    def test_workers_must_be_positive(self, plan):
        exe = plan.lower("multiprocess", workers=0).compile(
            quickstart_steps()
        )
        with pytest.raises(ValueError, match="workers"):
            exe.run()

    def test_schedule_pins_network_groups_to_processes(self):
        from repro.sched import NetworkModel

        inst = genomes_1000(n=2, m=2, a=1, b=1, c=1)
        net = NetworkModel.preset("two-rack").bind(sorted(inst.locations))
        plan = swirl.trace(inst).optimize().schedule(net)
        groups = assign_workers(
            plan.system, schedule=plan.schedule_report
        )
        # Every rack maps onto exactly one worker process.
        racks = {}
        for loc in plan.system.locations():
            racks.setdefault(net.group_of(loc), set()).add(loc)
        for members in racks.values():
            owners = {g for g in groups if members & set(g)}
            assert len(owners) == 1, f"rack {members} split across {owners}"

    def test_memory_transport_rejected(self, plan):
        exe = plan.lower("multiprocess", transport="memory").compile(
            quickstart_steps()
        )
        with pytest.raises(ValueError, match="cannot cross process"):
            exe.run()

    def test_unknown_option_rejected_at_lower_time(self, plan):
        with pytest.raises(TypeError, match="unknown options"):
            plan.lower("multiprocess", warp_speed=True)


# ---------------------------------------------------------------------------
# Fault injection: worker death, orphan hygiene, checkpoint/restore
# ---------------------------------------------------------------------------


class TestWorkerFailure:
    def test_killed_worker_names_location_and_step(self, plan):
        exe = plan.lower(
            "multiprocess", _kill_at_step="evaluate", timeout_s=60
        ).compile(quickstart_steps())
        with pytest.raises(WorkerFailedError) as e:
            exe.run()
        assert e.value.location == "gpu0"  # evaluate's location
        assert e.value.step == "evaluate"
        assert e.value.exitcode == -signal.SIGKILL
        _assert_no_workers_left(exe.program)

    def test_step_exception_surfaces_as_worker_failed(self, plan):
        steps = quickstart_steps()
        steps["train_b"] = lambda inp: (_ for _ in ()).throw(
            ValueError("boom")
        )
        exe = plan.lower("multiprocess", timeout_s=60).compile(steps)
        with pytest.raises(WorkerFailedError) as e:
            exe.run()
        assert e.value.location == "gpu1"
        assert e.value.step == "train_b"
        assert "boom" in e.value.reason
        _assert_no_workers_left(exe.program)

    def test_checkpoint_restore_resumes_to_same_result(self, plan, tmp_path):
        log = tmp_path / "execs.log"

        def logged_steps():
            steps = {}
            for name, fn in quickstart_steps().items():

                def wrapper(inp, _name=name, _fn=fn):
                    with open(log, "a") as f:
                        f.write(f"{_name}\n")
                    return _fn(inp)

                steps[name] = wrapper
            return steps

        clean = plan.lower("multiprocess").compile(quickstart_steps()).run()

        exe = plan.lower(
            "multiprocess", _kill_at_step="evaluate", timeout_s=60
        ).compile(logged_steps())
        with pytest.raises(WorkerFailedError):
            exe.run()
        ckpt = exe.checkpoint()
        # The upstream steps' deltas were harvested before the crash.
        assert {"preprocess", "train_a", "train_b"} <= set(
            ckpt.completed_execs
        )
        assert "evaluate" not in ckpt.completed_execs

        log.write_text("")  # only the resumed run's executions from here
        restored = (
            plan.lower("multiprocess", timeout_s=60)
            .compile(logged_steps())
            .restore(ckpt)
            .run()
        )
        assert restored.data == clean.data
        rerun = set(log.read_text().split())
        assert "preprocess" not in rerun, "completed step was re-executed"
        assert "train_a" not in rerun and "train_b" not in rerun
        assert "evaluate" in rerun
        _assert_no_workers_left(restored and exe.program)

    def test_checkpoint_after_success_skips_everything(self, plan, tmp_path):
        log = tmp_path / "execs.log"
        steps = {}
        for name, fn in quickstart_steps().items():

            def wrapper(inp, _name=name, _fn=fn):
                with open(log, "a") as f:
                    f.write(f"{_name}\n")
                return _fn(inp)

            steps[name] = wrapper

        exe = plan.lower("multiprocess").compile(steps)
        first = exe.run()
        ckpt = exe.checkpoint()
        assert set(ckpt.completed_execs) == set(EDGES)
        log.write_text("")
        restored = (
            plan.lower("multiprocess").compile(steps).restore(ckpt).run()
        )
        assert restored.data == first.data
        assert log.read_text() == "", "restore re-executed completed steps"

    def test_cross_backend_checkpoint_restore(self, plan):
        """An inprocess snapshot resumes on multiprocess (same final data)."""
        inproc = plan.lower("inprocess").compile(quickstart_steps())
        done = inproc.run()
        ckpt = inproc.checkpoint()
        restored = (
            plan.lower("multiprocess")
            .compile(quickstart_steps())
            .restore(ckpt)
            .run()
        )
        assert restored.data == done.data


# ---------------------------------------------------------------------------
# Elastic recovery: SIGKILLed workers are renamed onto spares / folded onto
# survivors mid-run, without re-executing checkpointed steps
# ---------------------------------------------------------------------------


def _logged_steps(log):
    """Step bodies that append their name to ``log`` on every *execution*
    (a replayed recorded output writes nothing)."""
    steps = {}
    for name, fn in quickstart_steps().items():

        def wrapper(inp, _name=name, _fn=fn):
            with open(log, "a") as f:
                f.write(f"{_name}\n")
            return _fn(inp)

        steps[name] = wrapper
    return steps


class TestElasticRecovery:
    def test_spare_recovery_survives_sigkill(self, plan, tmp_path):
        log = tmp_path / "execs.log"
        clean = plan.lower("multiprocess", timeout_s=60).compile(
            quickstart_steps()
        ).run()
        exe = plan.lower(
            "multiprocess",
            timeout_s=60,
            _kill_at_step="evaluate",
            recover="spare",
            spares=["spare0"],
            trace=True,
        ).compile(_logged_steps(log))
        result = exe.run()

        recs = result.stats["recoveries"]
        assert len(recs) == 1
        rec = recs[0]
        assert rec["mode"] == "spare"
        assert rec["failed_step"] == "evaluate"
        assert rec["dead"] == ["gpu0"]
        assert rec["renaming"] == {"gpu0": "spare0"}
        # Same results as the unperturbed run, modulo the renaming.
        assert result.data == {
            ("spare0" if l == "gpu0" else l): d for l, d in clean.data.items()
        }
        # Checkpointed steps were replayed, never re-executed: every step
        # body ran exactly once across both fleets (`evaluate` was killed
        # *before* its body, so its single run is post-recovery).
        executed = log.read_text().split()
        assert sorted(executed) == sorted(quickstart_steps())
        # The recovery is visible as a phase span on the renamed location.
        spans = [
            s for s in result.profile.spans if s.name == "recover:spare"
        ]
        assert len(spans) == 1
        assert spans[0].kind == "phase"
        assert (spans[0].src, spans[0].dst) == ("gpu0", "spare0")
        _assert_no_workers_left(exe.program)

    def test_fold_recovery_merges_onto_survivor(self, plan, tmp_path):
        log = tmp_path / "execs.log"
        clean = plan.lower("multiprocess", timeout_s=60).compile(
            quickstart_steps()
        ).run()
        exe = plan.lower(
            "multiprocess",
            timeout_s=60,
            _kill_at_step="evaluate",
            recover="fold",
        ).compile(_logged_steps(log))
        result = exe.run()

        recs = result.stats["recoveries"]
        assert len(recs) == 1
        ren = recs[0]["renaming"]
        assert recs[0]["mode"] == "fold"
        assert set(ren) == {"gpu0"}
        target = ren["gpu0"]
        assert target in {"cpu0", "gpu1"}
        expected: dict = {}
        for l, d in clean.data.items():
            expected.setdefault(ren.get(l, l), {}).update(d)
        assert result.data == expected
        assert sorted(log.read_text().split()) == sorted(quickstart_steps())
        _assert_no_workers_left(exe.program)

    def test_error_failures_are_never_recovered(self, plan):
        # A deterministic step exception would just re-raise on the
        # replacement — only process *death* is recoverable.
        steps = quickstart_steps()
        steps["train_b"] = lambda inp: (_ for _ in ()).throw(
            ValueError("boom")
        )
        exe = plan.lower(
            "multiprocess",
            timeout_s=60,
            recover="spare",
            spares=["spare0"],
        ).compile(steps)
        with pytest.raises(WorkerFailedError) as e:
            exe.run()
        assert "boom" in e.value.reason
        _assert_no_workers_left(exe.program)

    def test_recovery_exhausted_spares_raises(self, plan):
        exe = plan.lower(
            "multiprocess",
            timeout_s=60,
            _kill_at_step="evaluate",
            recover="spare",
            spares=[],
            max_recoveries=0,
        ).compile(quickstart_steps())
        with pytest.raises(WorkerFailedError) as e:
            exe.run()
        assert e.value.exitcode == -signal.SIGKILL
        _assert_no_workers_left(exe.program)

    def test_bad_recover_mode_rejected(self, plan):
        exe = plan.lower(
            "multiprocess", recover="wishful"
        ).compile(quickstart_steps())
        with pytest.raises(ValueError, match="recover must be"):
            exe.run()

    def test_run_many_batch_keeps_draining_through_kills(self, plan):
        clean = plan.lower("multiprocess", timeout_s=60).compile(
            quickstart_steps()
        ).run()
        exe = plan.lower(
            "multiprocess",
            timeout_s=120,
            _kill_at_step="evaluate",
            recover="fold",
        ).compile(quickstart_steps())
        results = exe.run_many([None] * 3)
        assert len(results) == 3
        for r in results:
            assert len(r.stats["recoveries"]) == 1
            ren = r.stats["recoveries"][0]["renaming"]
            expected: dict = {}
            for l, d in clean.data.items():
                expected.setdefault(ren.get(l, l), {}).update(d)
            assert r.data == expected
        _assert_no_workers_left(exe.program)


# ---------------------------------------------------------------------------
# Elastic recovery over the zero-copy shared-memory transport
# ---------------------------------------------------------------------------


class TestZeroCopyElasticRecovery:
    """SIGKILL a worker that owns live /dev/shm arenas, then recover.

    ``preprocess`` on cpu0 broadcasts a 512KB array out of cpu0's shm
    arenas; ``report`` also runs on cpu0, so killing at ``report`` takes
    down a worker whose shared-memory segments are still on disk.  The
    recovery respawn must produce the clean run's arrays (modulo the
    renaming) and the coordinator's namespace sweep must leave nothing
    behind in /dev/shm.
    """

    @staticmethod
    def _array_steps():
        return {
            "preprocess": lambda inp: {
                "d^preprocess": np.arange(65536, dtype=np.float64)
            },
            "train_a": lambda inp: {"d^train_a": inp["d^preprocess"] * 2.0},
            "train_b": lambda inp: {"d^train_b": inp["d^preprocess"] + 1.0},
            "evaluate": lambda inp: {
                "d^evaluate": inp["d^train_a"] + inp["d^train_b"]
            },
            "report": lambda inp: {},
        }

    @staticmethod
    def _data_equal(got, want):
        if got.keys() != want.keys():
            return False
        for loc, payloads in want.items():
            if got[loc].keys() != payloads.keys():
                return False
            for d, v in payloads.items():
                if not np.array_equal(
                    np.asarray(got[loc][d]), np.asarray(v)
                ):
                    return False
        return True

    @pytest.mark.parametrize(
        "mode,opts",
        [
            ("spare", {"recover": "spare", "spares": ["spare0"]}),
            ("fold", {"recover": "fold"}),
        ],
    )
    def test_recovery_with_live_segments_leaves_no_shm(
        self, plan, mode, opts
    ):
        before = set(glob.glob("/dev/shm/swirl-*"))
        clean = (
            plan.lower("multiprocess", timeout_s=60, zero_copy=True)
            .compile(self._array_steps())
            .run()
        )
        exe = plan.lower(
            "multiprocess",
            timeout_s=120,
            zero_copy=True,
            _kill_at_step="report",
            **opts,
        ).compile(self._array_steps())
        result = exe.run()

        recs = result.stats["recoveries"]
        assert len(recs) == 1
        assert recs[0]["mode"] == mode
        ren = recs[0]["renaming"]
        assert set(ren) == {"cpu0"}
        expected: dict = {}
        for l, d in clean.data.items():
            expected.setdefault(ren.get(l, l), {}).update(d)
        assert self._data_equal(result.data, expected)
        _assert_no_workers_left(exe.program)
        assert set(glob.glob("/dev/shm/swirl-*")) == before
