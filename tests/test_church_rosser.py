"""Lemma 1 (Church-Rosser): concurrent transitions commute to cofinal states.

Mechanical check of the paper's proof: for every reachable state of an
encoded system and every pair of coinitial transitions, executing them in
either order reaches the same state (up to structural congruence).
"""

import random


from repro.core import encode
from repro.core.semantics import apply_transition, enabled_transitions

from conftest import given, instances, settings


def _residual(w, t_done, t_other):
    """Find ``t_other``'s residual after ``t_done`` (same label)."""
    for t in enabled_transitions(w):
        if t.label == t_other.label:
            return t
    return None


@settings(max_examples=20, deadline=None)
@given(inst=instances(max_layers=2, max_width=2, max_locations=3))
def test_diamond_property(inst):
    w = encode(inst)
    rng = random.Random(0)
    # walk a random trajectory; at each state check all coinitial pairs
    for _ in range(20):
        ts = enabled_transitions(w)
        if not ts:
            break
        for i in range(len(ts)):
            for j in range(i + 1, len(ts)):
                t1, t2 = ts[i], ts[j]
                w1 = apply_transition(w, t1)
                w2 = apply_transition(w, t2)
                t2r = _residual(w1, t1, t2)
                t1r = _residual(w2, t2, t1)
                # both residuals must exist (concurrency relation, Def. 14)
                assert t2r is not None, (t1.label, t2.label)
                assert t1r is not None, (t1.label, t2.label)
                w12 = apply_transition(w1, t2r)
                w21 = apply_transition(w2, t1r)
                assert w12.canonical() == w21.canonical(), (
                    t1.label,
                    t2.label,
                )
        w = apply_transition(w, rng.choice(ts))


def test_diamond_on_paper_example():
    from test_graph import fig1_instance

    w = encode(fig1_instance())
    # after exec(s1), the three sends are pairwise concurrent
    ts = enabled_transitions(w)
    assert len(ts) == 1
    w = apply_transition(w, ts[0])
    ts = enabled_transitions(w)
    assert len(ts) == 3  # three sends matching three recvs
    t1, t2 = ts[0], ts[1]
    w1 = apply_transition(w, t1)
    w2 = apply_transition(w, t2)
    w12 = apply_transition(w1, _residual(w1, t1, t2))
    w21 = apply_transition(w2, _residual(w2, t2, t1))
    assert w12.canonical() == w21.canonical()
