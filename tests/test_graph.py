"""Workflow graph model — Defs. 1-7 invariants."""

import pytest

from repro.core.graph import (
    DistributedWorkflowInstance,
    Workflow,
    WorkflowInstance,
    make_workflow,
)


def fig1_workflow():
    return make_workflow(
        ["s1", "s2", "s3"],
        ["p1", "p2"],
        [("s1", "p1"), ("s1", "p2"), ("p1", "s2"), ("p2", "s3")],
    )


def fig1_instance():
    return DistributedWorkflowInstance(
        workflow=fig1_workflow(),
        locations=frozenset(["ld", "l1", "l2", "l3"]),
        mapping={"s1": ("ld",), "s2": ("l1",), "s3": ("l2", "l3")},
        data=frozenset(["d1", "d2"]),
        placement={"d1": "p1", "d2": "p2"},
    )


class TestDef1to2:
    def test_in_out_ports(self):
        w = fig1_workflow()
        assert w.in_ports("s1") == frozenset()
        assert w.out_ports("s1") == {"p1", "p2"}
        assert w.in_ports("s2") == {"p1"}
        assert w.in_steps("p1") == {"s1"}
        assert w.out_steps("p2") == {"s3"}

    def test_steps_ports_disjoint(self):
        with pytest.raises(ValueError, match="disjoint"):
            make_workflow(["a"], ["a"], [])

    def test_dep_domain(self):
        with pytest.raises(ValueError, match="not"):
            make_workflow(["s"], ["p"], [("s", "s")])

    def test_port_fanout_allowed(self):
        # "one port can have multiple output edges"
        w = make_workflow(
            ["a", "b", "c"], ["p"], [("a", "p"), ("p", "b"), ("p", "c")]
        )
        assert w.out_steps("p") == {"b", "c"}

    def test_topological_order(self):
        w = fig1_workflow()
        topo = w.topological_steps()
        assert topo.index("s1") < topo.index("s2")
        assert topo.index("s1") < topo.index("s3")

    def test_cycle_detected(self):
        w = make_workflow(
            ["a", "b"], ["p", "q"],
            [("a", "p"), ("p", "b"), ("b", "q"), ("q", "a")],
        )
        with pytest.raises(ValueError, match="cycle"):
            w.topological_steps()

    def test_multi_port_edge_between_same_pair_is_not_a_cycle(self):
        # One producer feeding one consumer through TWO ports is a single
        # completion event, not two — the per-(port, producer) in-degree
        # counting used to leave b's counter positive forever and
        # misreport this acyclic DAG as cyclic.
        w = make_workflow(
            ["a", "b"], ["p", "q"],
            [("a", "p"), ("a", "q"), ("p", "b"), ("q", "b")],
        )
        assert w.topological_steps() == ("a", "b")


class TestDef3to4:
    def test_in_out_data(self):
        inst = fig1_instance()
        assert inst.in_data("s2") == {"d1"}
        assert inst.out_data("s1") == {"d1", "d2"}
        assert inst.in_data("s1") == frozenset()

    def test_placement_validation(self):
        w = fig1_workflow()
        with pytest.raises(ValueError, match="unknown port"):
            WorkflowInstance(w, frozenset(["d"]), {"d": "nope"})
        with pytest.raises(ValueError, match="without a port"):
            WorkflowInstance(w, frozenset(["d"]), {})


class TestDef5to7:
    def test_work_queue(self):
        inst = fig1_instance()
        assert inst.work_queue("ld") == ("s1",)
        assert inst.work_queue("l2") == ("s3",)
        assert inst.locs_of("s3") == ("l2", "l3")

    def test_unmapped_step_rejected(self):
        with pytest.raises(ValueError, match="without a location"):
            DistributedWorkflowInstance(
                workflow=fig1_workflow(),
                locations=frozenset(["l"]),
                mapping={"s1": ("l",)},
                data=frozenset(),
                placement={},
            )

    def test_initial_data_validation(self):
        with pytest.raises(ValueError, match="unknown location"):
            fig1_instance().with_initial_data({"nope": ["d1"]})

    def test_producers_consumers_of_data(self):
        inst = fig1_instance()
        assert inst.producers_of_data("d2") == {"s1"}
        assert inst.consumers_of_data("d2") == {"s3"}
