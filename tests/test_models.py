"""Model zoo behaviour: decode path ≡ train-path forward, per family."""

import jax
import jax.numpy as jnp
import pytest

from repro.models import Model, ModelConfig, MoECfg, SSMCfg

BASE = dict(
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    dtype="float32", remat=False,
)

FAMILIES = {
    "dense": BASE,
    "gemma_style": {
        **BASE,
        "pattern": (("attn_local", "mlp"), ("attn", "mlp")),
        "sliding_window": 8,
        "attn_logit_softcap": 50.0,
        "final_logit_softcap": 30.0,
        "post_block_norm": True,
        "embed_scale": True,
        "tied_embeddings": True,
    },
    "relu2_layernorm_bias": {
        **BASE, "activation": "relu_sq", "norm": "layernorm", "qkv_bias": True,
    },
    "moe": {
        **BASE,
        "pattern": (("attn", "moe"),),
        "moe": MoECfg(n_experts=4, top_k=2, d_expert=32, n_shared=1,
                      capacity_factor=4.0),
    },
    "mamba": {
        **BASE, "pattern": (("mamba", "mlp"),), "ssm": SSMCfg(chunk=4),
    },
    "xlstm": {
        **BASE, "d_ff": 0, "n_kv_heads": 4,
        "pattern": (("mlstm", "none"), ("slstm", "none")),
        "ssm": SSMCfg(chunk=4),
    },
    "encdec_audio": {
        **BASE, "is_encoder_decoder": True, "n_enc_layers": 2,
        "frontend": "audio", "frontend_len": 8,
    },
    "vlm": {**BASE, "frontend": "vision", "frontend_len": 8},
    "prefix_dense0": {
        **BASE, "n_layers": 5, "prefix_pattern": (("attn", "dense0"),),
        "pattern": (("attn", "moe"),),
        "moe": MoECfg(n_experts=4, top_k=2, d_expert=16, n_shared=2,
                      capacity_factor=4.0),
    },
}


def _extras(cfg, b, key=2):
    kw = {}
    if cfg.is_encoder_decoder:
        kw["src_embeds"] = (
            jax.random.normal(jax.random.key(key), (b, cfg.frontend_len, cfg.d_model)) * 0.1
        )
    if cfg.frontend == "vision":
        kw["patch_embeds"] = (
            jax.random.normal(jax.random.key(key + 1), (b, cfg.frontend_len, cfg.d_model)) * 0.1
        )
    return kw


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_decode_matches_forward(family):
    cfg = ModelConfig(name=family, **FAMILIES[family])
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    b, l = 2, 16
    tokens = jax.random.randint(jax.random.key(1), (b, l), 0, cfg.vocab)
    kw = _extras(cfg, b)
    full, _ = m.forward(params, tokens, **kw)

    cache = m.init_cache(b, 64)
    lg, cache = m.prefill(params, tokens[:, :8], cache, **kw)
    outs = [lg]
    for t in range(8, l):
        lg, cache = m.decode_step(params, tokens[:, t : t + 1], cache)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    ref = full[:, -(l - 7) :]
    assert float(jnp.max(jnp.abs(dec - ref))) < 2e-3


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_train_step_finite_grads(family):
    cfg = ModelConfig(name=family, **FAMILIES[family])
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    b, l = 2, 16
    tokens = jax.random.randint(jax.random.key(1), (b, l), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens, **_extras(cfg, b)}
    (loss, metrics), grads = jax.value_and_grad(m.loss, has_aux=True)(
        params, batch
    )
    assert jnp.isfinite(loss)
    assert all(
        bool(jnp.all(jnp.isfinite(g))) for g in jax.tree.leaves(grads)
    )
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert gnorm > 0.0


def test_label_masking():
    cfg = ModelConfig(name="mask", **BASE)
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab)
    all_masked = {"tokens": tokens, "labels": jnp.full_like(tokens, -1)}
    loss, metrics = m.loss(params, all_masked)
    assert float(metrics["tokens"]) == 0.0
    assert float(loss) == 0.0


def test_remat_matches_no_remat():
    import dataclasses

    cfg = ModelConfig(name="remat", **{**BASE, "n_layers": 4})
    m1 = Model(cfg)
    m2 = Model(dataclasses.replace(cfg, remat=True))
    params = m1.init(jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    l1, _ = m1.loss(params, batch)
    l2, _ = m2.loss(params, batch)
    assert abs(float(l1) - float(l2)) < 1e-5
    g1 = jax.grad(lambda p: m1.loss(p, batch)[0])(params)
    g2 = jax.grad(lambda p: m2.loss(p, batch)[0])(params)
    diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), g1, g2)
    assert max(jax.tree.leaves(diffs)) < 1e-4
