""".swirl surface syntax: round-trips and error reporting."""

import pytest

from repro.core import encode, optimize
from repro.core.parser import SwirlSyntaxError, dumps, loads, parse_trace
from repro.core.syntax import normalize
from repro.core.translate import genomes_1000

from conftest import given, instances, settings


def test_roundtrip_fig1():
    from test_graph import fig1_instance

    w = encode(fig1_instance())
    assert loads(dumps(w)) == w


def test_roundtrip_genomes_optimised():
    o, _ = optimize(encode(genomes_1000()))
    assert loads(dumps(o)) == o


@settings(max_examples=30, deadline=None)
@given(inst=instances())
def test_roundtrip_random(inst):
    w = encode(inst)
    assert loads(dumps(w)) == w


def test_parse_trace_precedence():
    # '.' binds tighter than '|'
    t = parse_trace("recv(p,a,b).exec(s,{}->{},{b}) | send(d->p,b,b)")
    from repro.core.syntax import Par

    assert isinstance(t, Par)
    assert len(t.branches) == 2


def test_parse_nil():
    t = parse_trace("0.exec(s,{}->{},{l}).0")
    from repro.core.syntax import Exec

    assert isinstance(normalize(t), Exec)


def test_parens_grouping():
    a = parse_trace("exec(a,{}->{},{l}).(exec(b,{}->{},{l}) | exec(c,{}->{},{l}))")
    b = parse_trace("exec(a,{}->{},{l}).exec(b,{}->{},{l}) | exec(c,{}->{},{l})")
    assert normalize(a) != normalize(b)


@pytest.mark.parametrize(
    "bad",
    [
        "<l,{},exec(s,{}->{},{l})",  # missing >
        "<l,{},exec(s,{}{},{l})>",  # missing ->
        "<l,{},bogus(s)>",
        "<l,{},exec(s,{}->{},{l})> trailing",
        "<l,{d d},0>",
    ],
)
def test_syntax_errors(bad):
    with pytest.raises(SwirlSyntaxError):
        loads(bad)


def test_error_reports_position():
    """Syntax errors carry structured 1-based line/column (and the raw
    offset) — the serving gateway forwards them in its 400 JSON bodies."""
    try:
        loads("<l,{},exec(s,{}->{},{l})> | <l2,{},bogus>")
    except SwirlSyntaxError as e:
        assert "line 1" in str(e) and "column" in str(e)
        assert e.line == 1
        assert e.column is not None and e.column > 28  # past the 2nd <
        assert e.offset == e.column - 1  # single-line source
    else:
        raise AssertionError("expected syntax error")


def test_error_position_is_multiline_aware():
    src = "# header comment\n<l, {d1},\n  bogus(s)>\n"
    try:
        loads(src)
    except SwirlSyntaxError as e:
        assert e.line == 3
        assert e.column == 3  # 'bogus' after two spaces
        lines = src.splitlines()
        assert lines[e.line - 1][e.column - 1 :].startswith("bogus")
    else:
        raise AssertionError("expected syntax error")


@pytest.mark.parametrize(
    "bad",
    [
        "<l,{},exec(s,{}->{},{l})",
        "<l,{},exec(s,{}{},{l})>",
        "<l,{},bogus(s)>",
        "<l,{d d},0>",
    ],
)
def test_all_errors_carry_positions(bad):
    with pytest.raises(SwirlSyntaxError) as exc:
        loads(bad)
    e = exc.value
    assert e.line is not None and e.line >= 1
    assert e.column is not None and e.column >= 1
    assert e.offset is not None and 0 <= e.offset <= len(bad)


def test_comments_and_whitespace():
    w = loads(
        """
        # a comment
        <l, {d1, d2},   # resident data
         exec(s, {d1} -> {}, {l})>
        """
    )
    assert w["l"].data == {"d1", "d2"}
