"""MoE dispatch: capacity semantics vs a dense routing reference."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig, MoECfg
from repro.models.layers import linear
from repro.models.moe import apply_moe, init_moe, moe_capacity


def _cfg(e=4, k=2, cf=4.0, shared=0):
    return ModelConfig(
        name="moe", n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
        d_ff=0, vocab=64, dtype="float32", remat=False,
        pattern=(("attn", "moe"),),
        moe=MoECfg(n_experts=e, top_k=k, d_expert=16, n_shared=shared,
                   capacity_factor=cf),
    )


def _dense_reference(cfg, p, x):
    """Route every token to its top-k experts with no capacity limit."""
    m = cfg.moe
    b, l, d = x.shape
    xf = x.reshape(-1, d)
    logits = linear(p["router"], xf.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, m.top_k)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    y = jnp.zeros_like(xf)
    for e in range(m.n_experts):
        h = jax.nn.silu(xf @ p["gate"][e]) * (xf @ p["up"][e])
        oe = h @ p["down"][e]
        for j in range(m.top_k):
            sel = (idx[:, j] == e).astype(xf.dtype)[:, None]
            y = y + oe * sel * w[:, j : j + 1].astype(xf.dtype)
    if "shared" in p:
        sh = p["shared"]
        y = y + linear(
            sh["down"], jax.nn.silu(linear(sh["gate"], xf)) * linear(sh["up"], xf)
        )
    return y.reshape(b, l, d)


def test_matches_dense_reference_with_ample_capacity():
    cfg = _cfg(cf=8.0, shared=1)
    p = init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model)) * 0.5
    y, aux = apply_moe(cfg, p, x)
    ref = _dense_reference(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4)
    assert float(aux) > 0.0


def test_capacity_drops_reduce_output():
    """With capacity 0-ish, routed output vanishes (residual falls through)."""
    cfg = _cfg(cf=0.01)
    p = init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model))
    y, _ = apply_moe(cfg, p, x)
    cfg_full = _cfg(cf=8.0)
    y_full, _ = apply_moe(cfg_full, p, x)
    assert float(jnp.mean(jnp.abs(y))) < float(jnp.mean(jnp.abs(y_full)))


def test_capacity_formula():
    cfg = _cfg(e=8, k=2, cf=1.25)
    c = moe_capacity(cfg, 1024)
    assert c >= 1024 * 2 / 8 * 1.25
    assert c % 4 == 0


def test_aux_loss_balanced_vs_skewed():
    """Uniform routing gives aux ≈ 1; collapsed routing gives aux > 1."""
    cfg = _cfg(e=4, k=1, cf=8.0)
    p = init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (4, 64, cfg.d_model))
    _, aux_rand = apply_moe(cfg, p, x)
    # force collapse: bias router to expert 0
    p2 = dict(p)
    p2["router"] = {
        "w": jnp.zeros_like(p["router"]["w"]).at[:, 0].set(0.0)
        + jnp.array([10.0, 0, 0, 0])[None, :] * 0
    }
    p2["router"] = {"w": jnp.zeros((cfg.d_model, 4)).at[:, 0].add(1.0)}
    _, aux_collapsed = apply_moe(cfg, p2, x)
    assert float(aux_collapsed) > float(aux_rand)


def test_grads_flow_through_router():
    cfg = _cfg(cf=4.0)
    p = init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model))

    def loss(p):
        y, aux = apply_moe(cfg, p, x)
        return jnp.sum(y * y) + 0.01 * aux

    g = jax.grad(loss)(p)
    assert float(jnp.sum(jnp.abs(g["router"]["w"]))) > 0.0
    assert float(jnp.sum(jnp.abs(g["gate"]))) > 0.0
