"""Cross-backend differential testing: every backend, same final stores.

Random layered DAG workflows (5–20 steps, random fan-in/out, random
location counts, occasional spatial constraints) go through
trace → optimize → lower on **every registered backend** — all of which
interpret the flat per-location program IR of :mod:`repro.exec`, including
the multiprocess backend's real OS processes — and must produce identical
final data stores.  The R1R2/R3-rewritten plan must also match the
unrewritten plan on every backend (the Thm.-1 guarantee made observable).

The flat-program interpreters are additionally checked against the
**legacy tree-walking oracles** kept for exactly this purpose: the
decentralised bundle interpreter (``ThreadedRuntime`` over
``compile_bundles`` output) and the reduction-semantics runtime
(``Runtime``) — flat-program execution ≡ legacy bundle execution on every
sampled DAG, rewritten and unrewritten.

Two generators drive the same property:

* a seeded ``random.Random`` sweep (``CHUNKS × CHUNK_SIZE`` ≥ 100 DAGs),
  deterministic everywhere and independent of hypothesis;
* a hypothesis strategy (the shared ``instances`` strategy from conftest)
  that additionally shrinks failures; it skips when hypothesis is missing.
"""

from __future__ import annotations

import random

import pytest
from conftest import given, identity_step_fns, instances, settings

from repro import swirl
from repro._compat import suppress_deprecations
from repro.backends import available_backends
from repro.core.graph import DistributedWorkflowInstance, make_workflow

#: Options per backend: real-process backends get generous timeouts so a
#: loaded CI machine cannot turn a pass into a hang-report.
BACKEND_OPTIONS = {
    "threaded": {"timeout_s": 60},
    "multiprocess": {"timeout_s": 120},
}

#: Data-plane arms layered on top of every registered backend's default
#: configuration: the multiprocess backend over the zero-copy
#: shared-memory transport, and the JAX backend with fused location
#: programs.  Both must produce byte-identical stores to the defaults on
#: every sampled DAG — the fast path is not allowed to change results.
EXTRA_ARMS = [
    ("multiprocess[shm]", "multiprocess", {"zero_copy": True}),
    ("jax[fused]", "jax", {"fuse": True}),
]

CHUNKS = 20
CHUNK_SIZE = 5  # CHUNKS × CHUNK_SIZE = 100 DAGs ≥ the acceptance floor


def random_instance(rng: random.Random) -> DistributedWorkflowInstance:
    """One random layered DAG instance: 5–20 steps, 1–4 locations."""
    n_steps = rng.randint(5, 20)
    n_locs = rng.randint(1, 4)
    locations = [f"l{i}" for i in range(n_locs)]

    widths: list[int] = []
    remaining = n_steps
    while remaining:
        w = min(remaining, rng.randint(1, 4))
        widths.append(w)
        remaining -= w

    steps: list[str] = []
    ports: list[str] = []
    deps: list[tuple[str, str]] = []
    data: list[str] = []
    placement: dict[str, str] = {}
    mapping: dict[str, tuple[str, ...]] = {}
    prev_ports: list[str] = []
    sid = 0
    for layer, width in enumerate(widths):
        new_ports: list[str] = []
        for _ in range(width):
            s = f"s{sid}"
            sid += 1
            steps.append(s)
            if n_locs > 1 and rng.random() < 0.15:
                # Spatial constraint: the step runs on two locations.
                mapping[s] = tuple(sorted(rng.sample(locations, 2)))
            else:
                mapping[s] = (rng.choice(locations),)
            if prev_ports:
                n_in = rng.randint(0, min(3, len(prev_ports)))
                for p in rng.sample(prev_ports, n_in):
                    deps.append((p, s))
            if layer < len(widths) - 1 or rng.random() < 0.5:
                p, d = f"p{s}", f"d{s}"
                ports.append(p)
                data.append(d)
                placement[d] = p
                deps.append((s, p))
                new_ports.append(p)
        prev_ports = new_ports
    wf = make_workflow(steps, ports, deps)
    return DistributedWorkflowInstance(
        workflow=wf,
        locations=frozenset(locations),
        mapping=mapping,
        data=frozenset(data),
        placement=placement,
        initial_data={},
    )


def _run(plan, inst, backend, extra_options=None):
    options = dict(BACKEND_OPTIONS.get(backend, {}))
    if extra_options:
        options.update(extra_options)
    lowered = plan.lower(backend, **options)
    return lowered.compile(identity_step_fns(inst)).run().data


def _assert_backends_agree(
    inst, *, check_raw: bool, extra_arms: bool = True
) -> None:
    raw = swirl.trace(inst)
    opt = raw.optimize(("R1R2", "R3"))
    backends = available_backends()
    results = {b: _run(opt, inst, b) for b in backends}
    reference_backend = backends[0]
    reference = results[reference_backend]
    for b, got in results.items():
        assert got == reference, (
            f"{b} diverged from {reference_backend} on the optimized plan"
        )
    if extra_arms:
        for label, backend, options in EXTRA_ARMS:
            if backend not in backends:
                continue
            got = _run(opt, inst, backend, options)
            assert got == reference, (
                f"{label} diverged from {reference_backend} on the "
                "optimized plan"
            )
    if check_raw:
        for b in backends:
            assert _run(raw, inst, b) == reference, (
                f"{b}: R1R2/R3-rewritten plan diverged from the "
                "unrewritten plan"
            )


# ---------------------------------------------------------------------------
# Seeded sweep — ≥100 DAGs, runs with or without hypothesis
# ---------------------------------------------------------------------------


class TestSeededSweep:
    @pytest.mark.parametrize("chunk", range(CHUNKS))
    def test_all_backends_agree(self, chunk):
        for i in range(CHUNK_SIZE):
            rng = random.Random(1000 * chunk + i)
            inst = random_instance(rng)
            # The raw-vs-rewritten cross-check costs a second full sweep of
            # backend runs; one DAG per chunk keeps it at 20/100 DAGs.
            _assert_backends_agree(inst, check_raw=(i == 0))

    def test_generator_respects_bounds(self):
        for seed in range(200):
            inst = random_instance(random.Random(seed))
            assert 5 <= len(inst.workflow.steps) <= 20
            assert 1 <= len(inst.locations) <= 4


# ---------------------------------------------------------------------------
# Flat-program execution ≡ legacy bundle / reduction execution
# ---------------------------------------------------------------------------


def _legacy_threaded(plan, inst) -> dict:
    """Run via the deprecated tree-walking bundle interpreter (oracle)."""
    from repro.core.compile import build_bundles
    from repro.workflow.threaded import ThreadedRuntime

    fns = identity_step_fns(inst)
    with suppress_deprecations():
        bundles = build_bundles(plan.system, fns)
        rt = ThreadedRuntime(bundles, timeout_s=60)
        data = rt.run()
    return {loc: dict(d) for loc, d in data.items()}


def _legacy_reduction(plan, inst) -> dict:
    """Run via the deprecated reduction-semantics runtime (oracle)."""
    from repro.workflow.runtime import Runtime

    fns = identity_step_fns(inst)
    with suppress_deprecations():
        rt = Runtime(plan.system, fns)
        rt.run()
    return {
        loc: rt.location_data(loc) for loc in plan.system.locations()
    }


class TestFlatProgramVsLegacyOracles:
    """The program-IR interpreters match the retired tree walkers."""

    @pytest.mark.parametrize("chunk", range(5))
    def test_threaded_program_matches_tree_bundles(self, chunk):
        for i in range(4):
            rng = random.Random(7000 * chunk + i)
            inst = random_instance(rng)
            for plan in self._plans(inst):
                got = _run(plan, inst, "threaded")
                want = _legacy_threaded(plan, inst)
                assert got == want, (
                    "flat-program threaded execution diverged from the "
                    "legacy bundle interpreter"
                )

    @pytest.mark.parametrize("chunk", range(5))
    def test_inprocess_program_matches_reduction_runtime(self, chunk):
        for i in range(4):
            rng = random.Random(9000 * chunk + i)
            inst = random_instance(rng)
            for plan in self._plans(inst):
                got = _run(plan, inst, "inprocess")
                want = _legacy_reduction(plan, inst)
                # The reduction oracle only stores payloads it produced;
                # the backend also reports empty scopes per location.
                for loc, payloads in want.items():
                    assert got.get(loc, {}) == payloads, (
                        "flat-program inprocess execution diverged from "
                        "the reduction-semantics oracle"
                    )

    @staticmethod
    def _plans(inst):
        raw = swirl.trace(inst)
        return (raw, raw.optimize(("R1R2", "R3")))


# ---------------------------------------------------------------------------
# Hypothesis — same property, shrinking counterexamples
# ---------------------------------------------------------------------------


class TestHypothesisDifferential:
    @given(inst=instances(max_layers=4, max_width=3, max_locations=3))
    @settings(max_examples=15, deadline=None)
    def test_all_backends_agree(self, inst):
        _assert_backends_agree(inst, check_raw=False)

    @given(inst=instances(max_layers=3, max_width=3, max_locations=3))
    @settings(max_examples=10, deadline=None)
    def test_rewritten_matches_unrewritten_everywhere(self, inst):
        _assert_backends_agree(inst, check_raw=True)
