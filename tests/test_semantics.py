"""Reduction semantics — Figs. 2-3 rule behaviours."""

import random

import pytest

from repro.core import encode, run
from repro.core.parser import parse_system
from repro.core.semantics import (
    CommTransition,
    ExecTransition,
    apply_transition,
    barbs,
    enabled_transitions,
)
from repro.core.syntax import congruent, normalize

from conftest import given, instances, settings
from test_graph import fig1_instance


class TestExecRule:
    def test_exec_blocked_without_input_data(self):
        w = parse_system("<l,{},exec(s,{d}->{},{l})>")
        assert enabled_transitions(w) == []

    def test_exec_enabled_with_data(self):
        w = parse_system("<l,{d},exec(s,{d}->{e},{l})>")
        ts = enabled_transitions(w)
        assert len(ts) == 1 and isinstance(ts[0], ExecTransition)
        w2 = apply_transition(w, ts[0])
        assert w2["l"].data == {"d", "e"}
        assert w2.is_terminated()

    def test_exec_synchronises_all_locations(self):
        # both locations must be at the exec for it to fire
        w = parse_system(
            "<a,{d},exec(s,{d}->{e},{a,b})> | "
            "<b,{d},recv(p,a,b).exec(s,{d}->{e},{a,b})>"
        )
        assert all(not isinstance(t, ExecTransition) for t in enabled_transitions(w))

    def test_exec_adds_outputs_everywhere(self):
        w = parse_system(
            "<a,{d},exec(s,{d}->{e},{a,b})> | <b,{d},exec(s,{d}->{e},{a,b})>"
        )
        ts = [t for t in enabled_transitions(w) if isinstance(t, ExecTransition)]
        assert len(ts) == 1
        w2 = apply_transition(w, ts[0])
        assert w2["a"].data == {"d", "e"} and w2["b"].data == {"d", "e"}


class TestCommRule:
    def test_comm_copies_not_consumes(self):
        w = parse_system(
            "<a,{d},send(d->p,a,b)> | <b,{},recv(p,a,b)>"
        )
        ts = enabled_transitions(w)
        assert len(ts) == 1 and isinstance(ts[0], CommTransition)
        w2 = apply_transition(w, ts[0])
        assert w2["a"].data == {"d"}  # still there (copy semantics)
        assert w2["b"].data == {"d"}

    def test_send_blocked_without_datum(self):
        w = parse_system("<a,{},send(d->p,a,b)> | <b,{},recv(p,a,b)>")
        assert enabled_transitions(w) == []

    def test_l_comm_same_location(self):
        w = parse_system("<a,{d},send(d->p,a,a) | recv(p,a,a)>")
        ts = enabled_transitions(w)
        assert len(ts) == 1
        w2 = apply_transition(w, ts[0])
        assert w2.is_terminated()

    def test_comm_matches_on_port_src_dst(self):
        w = parse_system(
            "<a,{d},send(d->p,a,b)> | <b,{},recv(q,a,b)>"
        )
        assert enabled_transitions(w) == []  # port mismatch


class TestSequencingAndCongruence:
    def test_seq_guards(self):
        w = parse_system("<a,{d,e},exec(s1,{d}->{},{a}).exec(s2,{e}->{},{a})>")
        ts = enabled_transitions(w)
        assert len(ts) == 1 and ts[0].step == "s1"

    def test_par_interleaves(self):
        w = parse_system(
            "<a,{d,e},exec(s1,{d}->{},{a}) | exec(s2,{e}->{},{a})>"
        )
        steps = {t.step for t in enabled_transitions(w)}
        assert steps == {"s1", "s2"}

    def test_barbs_are_execs(self):
        w = parse_system(
            "<a,{d},exec(s,{d}->{},{a}) | send(d->p,a,a) | recv(p,a,a)>"
        )
        bs = barbs(w)
        assert len(bs) == 1 and next(iter(bs))[0] == "exec"

    def test_congruence_identity_and_commut(self):
        a = parse_trace_sys("<l,{},(0.exec(s,{}->{},{l})) | 0>")
        b = parse_trace_sys("<l,{},exec(s,{}->{},{l})>")
        assert a.canonical() == b.canonical()


def parse_trace_sys(s):
    return parse_system(s)


class TestEncodedSystemsTerminate:
    def test_fig1_runs_to_completion(self):
        w = encode(fig1_instance())
        r = run(w, rng=random.Random(7))
        assert not r.deadlocked
        # s3 is one synchronised exec across l2,l3 → 3 exec events total
        assert len(r.exec_events) == 3

    @settings(max_examples=25, deadline=None)
    @given(inst=instances())
    def test_random_instances_terminate(self, inst):
        w = encode(inst)
        r = run(w, rng=random.Random(1), max_steps=50_000)
        assert not r.deadlocked
        # every step fires exactly once (synchronised execs count once)
        assert len(r.exec_events) == len(inst.workflow.steps)

    @settings(max_examples=10, deadline=None)
    @given(inst=instances())
    def test_schedules_converge(self, inst):
        """Church-Rosser consequence: any schedule, same final state."""
        w = encode(inst)
        finals = set()
        for seed in range(3):
            r = run(w, rng=random.Random(seed))
            finals.add(r.final.canonical())
        assert len(finals) == 1
