"""Data pipeline, optimizer, compression, checkpointing."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from conftest import given, settings, st

from repro.ckpt import async_save, latest_step, load_checkpoint, save_checkpoint
from repro.data import ShardedLoader, SyntheticLM
from repro.optim import (
    AdamWConfig,
    allreduce_mean,
    compress,
    compressed_bytes,
    decompress,
)
from repro.optim import adamw
from repro.optim.zero import zero1_specs


class TestData:
    def test_deterministic(self):
        ds = SyntheticLM(vocab=100, seq_len=8, global_batch=4, seed=3)
        assert np.array_equal(
            ds.batch(7)["tokens"], ds.batch(7)["tokens"]
        )
        assert not np.array_equal(
            ds.batch(7)["tokens"], ds.batch(8)["tokens"]
        )

    def test_shards_partition_global_batch(self):
        ds = SyntheticLM(vocab=100, seq_len=8, global_batch=8)
        full = ds.batch(0, 0, 1)["tokens"]
        parts = [ds.batch(0, s, 4)["tokens"] for s in range(4)]
        got = np.concatenate(parts, axis=0)
        assert sorted(map(tuple, got)) == sorted(map(tuple, full))

    def test_labels_shifted(self):
        ds = SyntheticLM(vocab=100, seq_len=8, global_batch=2)
        b = ds.batch(0)
        assert np.array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_loader_prefetch(self):
        ds = SyntheticLM(vocab=100, seq_len=8, global_batch=4)
        ld = ShardedLoader(ds, shard=1, n_shards=2, start_step=5)
        s, b = next(ld)
        assert s == 5
        assert np.array_equal(b["tokens"], ds.batch(5, 1, 2)["tokens"])
        ld.close()

    def test_vocab_bounds(self):
        ds = SyntheticLM(vocab=17, seq_len=64, global_batch=4)
        b = ds.batch(0)
        assert b["tokens"].min() >= 0 and b["tokens"].max() < 17


class TestAdamW:
    def test_converges_on_quadratic(self):
        cfg = AdamWConfig(lr=0.1, warmup_steps=5, total_steps=200,
                          weight_decay=0.0)
        params = {"w": jnp.array([5.0, -3.0])}
        state = adamw.init(params)
        loss = lambda p: jnp.sum(jnp.square(p["w"]))  # noqa: E731
        for _ in range(150):
            g = jax.grad(loss)(params)
            params, state, _ = adamw.update(cfg, g, state, params)
        assert float(loss(params)) < 1e-2

    def test_schedule_shape(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
        s = adamw.schedule
        assert float(s(cfg, jnp.array(0))) < 0.2
        assert abs(float(s(cfg, jnp.array(10))) - 1.0) < 1e-6
        assert abs(float(s(cfg, jnp.array(100))) - 0.1) < 1e-6

    def test_clipping(self):
        cfg = AdamWConfig(clip_norm=1.0)
        params = {"w": jnp.zeros(3)}
        state = adamw.init(params)
        g = {"w": jnp.array([100.0, 0, 0])}
        _, _, m = adamw.update(cfg, g, state, params)
        assert float(m["grad_norm"]) == pytest.approx(100.0)

    def test_mixed_precision_dtypes(self):
        params = {"w": jnp.ones((4,), jnp.bfloat16)}
        state = adamw.init(params)
        assert state.m["w"].dtype == jnp.float32
        g = {"w": jnp.ones((4,), jnp.bfloat16)}
        p2, s2, _ = adamw.update(AdamWConfig(), g, state, params)
        assert p2["w"].dtype == jnp.bfloat16


class TestCompression:
    def test_roundtrip_small_error(self):
        g = {"w": jnp.array([[0.5, -0.25, 0.125, 1.0]])}
        c, err = compress(g)
        deq = decompress(c)
        assert float(jnp.max(jnp.abs(deq["w"] - g["w"]))) < 1.0 / 127

    def test_error_feedback_telescopes(self):
        """Σ dequantised ≈ Σ true gradients (bias cancels via feedback)."""
        key = jax.random.key(0)
        true_sum = jnp.zeros(16)
        deq_sum = jnp.zeros(16)
        err = None
        for i in range(50):
            g = {"w": jax.random.normal(jax.random.fold_in(key, i), (16,))}
            c, err = compress(g, err)
            deq_sum = deq_sum + decompress(c)["w"]
            true_sum = true_sum + g["w"]
        # residual bounded by one quantisation step, NOT growing with steps
        assert float(jnp.max(jnp.abs(deq_sum - true_sum))) < 0.2

    def test_compression_ratio(self):
        g = {"w": jnp.zeros((128, 256), jnp.float32)}
        c, _ = compress(g)
        raw = 128 * 256 * 4
        assert compressed_bytes(c) < raw / 3

    def test_allreduce_mean(self):
        a = {"w": jnp.ones(4)}
        b = {"w": jnp.full((4,), 3.0)}
        m = allreduce_mean([a, b])
        assert np.allclose(np.asarray(m["w"]), 2.0)

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_quantisation_bounded(self, seed):
        g = {"w": jax.random.normal(jax.random.key(seed), (8, 8)) * 10}
        c, err = compress(g)
        step = jnp.max(jnp.abs(g["w"]), axis=-1, keepdims=True) / 127.0
        assert bool(jnp.all(jnp.abs(err["w"]) <= step + 1e-6))


class TestCheckpoint:
    def test_roundtrip_mixed_dtypes(self, tmp_path):
        tree = {
            "a": jnp.ones((3, 4), jnp.bfloat16),
            "b": {"c": jnp.arange(5), "d": (jnp.zeros(2), jnp.ones(2))},
        }
        save_checkpoint(tmp_path, 7, tree)
        back = load_checkpoint(tmp_path, 7, tree)
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            assert x.dtype == y.dtype
            assert np.allclose(
                np.asarray(x, np.float32), np.asarray(y, np.float32)
            )

    def test_latest_and_gc(self, tmp_path):
        tree = {"w": jnp.ones(2)}
        for s in (1, 2, 3, 4):
            save_checkpoint(tmp_path, s, tree, keep=2)
        assert latest_step(tmp_path) == 4
        kept = sorted(p.name for p in tmp_path.glob("step_*"))
        assert len(kept) == 2

    def test_interrupted_write_ignored(self, tmp_path):
        tree = {"w": jnp.ones(2)}
        save_checkpoint(tmp_path, 1, tree)
        (tmp_path / "step_000000099").mkdir()  # no manifest
        assert latest_step(tmp_path) == 1

    def test_async_save(self, tmp_path):
        tree = {"w": jnp.ones((64, 64))}
        saver = async_save(tmp_path, 3, tree)
        p = saver.wait(10)
        assert p.name == "step_000000003"
        back = load_checkpoint(tmp_path, 3, tree)
        assert np.allclose(np.asarray(back["w"]), 1.0)

    def test_missing_leaf_rejected(self, tmp_path):
        save_checkpoint(tmp_path, 1, {"w": jnp.ones(2)})
        with pytest.raises(KeyError):
            load_checkpoint(tmp_path, 1, {"w": jnp.ones(2), "extra": jnp.ones(1)})


class TestZeRO:
    def test_specs_add_data_axis(self):
        from jax.sharding import PartitionSpec as P

        specs = {"w": P(None, "model"), "b": P("model")}
        shapes = {"w": jax.ShapeDtypeStruct((128, 64), jnp.float32),
                  "b": jax.ShapeDtypeStruct((64,), jnp.float32)}
        z = zero1_specs(specs, shapes, data_axis="data", data_size=16)
        assert z["w"] == P("data", "model")
        assert z["b"] == P("model")  # 64 not divisible by 16 on a free dim? 64%16==0 → first dim taken
