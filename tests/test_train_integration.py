"""End-to-end: SWIRL-planned training loop (smoke config) — loss decreases,
checkpoints resume, compression on/off agree."""

import numpy as np
import pytest

from repro.launch.train import train


@pytest.fixture(scope="module")
def short_run(tmp_path_factory):
    d = tmp_path_factory.mktemp("ckpt")
    out = train(
        "llama3.2-3b", smoke=True, steps=8, n_pods=2,
        global_batch=4, seq_len=32, ckpt_dir=str(d), log_every=100,
    )
    return d, out


def test_loss_decreases(short_run):
    _, out = short_run
    losses = [float(h["loss"]) for h in out["history"]]
    # short smoke run: not monotone step-to-step, but training must make
    # net progress past warmup
    assert min(losses[3:]) < losses[0]


def test_checkpoint_written_and_resumes(short_run):
    d, out = short_run
    from repro.ckpt import latest_step

    assert latest_step(d) is not None
    # resume continues from the saved step
    out2 = train(
        "llama3.2-3b", smoke=True, steps=2, n_pods=2,
        global_batch=4, seq_len=32, ckpt_dir=str(d), log_every=100,
    )
    assert len(out2["history"]) == 2


def test_pods_agree_with_single_pod():
    """2-pod SWIRL plan ≡ 1-pod plan (data-parallel correctness): the
    *parameters* after the same number of steps must match — the logged
    per-pod loss is each pod's local half-batch CE and legitimately
    differs.  Compression disabled (int8 adds tiny per-pod noise)."""
    import jax

    a = train(
        "llama3.2-3b", smoke=True, steps=3, n_pods=1,
        global_batch=4, seq_len=32, ckpt_dir=None, log_every=100,
        compress_grads=False,
    )
    b = train(
        "llama3.2-3b", smoke=True, steps=3, n_pods=2,
        global_batch=4, seq_len=32, ckpt_dir=None, log_every=100,
        compress_grads=False,
    )
    diffs = jax.tree.map(
        lambda x, y: float(np.max(np.abs(np.asarray(x, np.float32) - np.asarray(y, np.float32)))),
        a["params"], b["params"],
    )
    assert max(jax.tree.leaves(diffs)) < 1e-5


def test_compressed_training_tracks_uncompressed():
    a = train(
        "llama3.2-3b", smoke=True, steps=6, n_pods=2,
        global_batch=4, seq_len=32, ckpt_dir=None, log_every=100,
        compress_grads=False,
    )
    b = train(
        "llama3.2-3b", smoke=True, steps=6, n_pods=2,
        global_batch=4, seq_len=32, ckpt_dir=None, log_every=100,
        compress_grads=True,
    )
    la = float(a["history"][-1]["loss"])
    lb = float(b["history"][-1]["loss"])
    assert abs(la - lb) / la < 0.05  # int8+EF stays close
