"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps, interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rmsnorm import rmsnorm


def _qkv(key, b, hq, hkv, lq, lk, d, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, hq, lq, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, hkv, lk, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, hkv, lk, d)).astype(dtype)
    return q, k, v


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


class TestFlashAttention:
    @pytest.mark.parametrize(
        "b,hq,hkv,lq,lk,d",
        [
            (1, 2, 2, 128, 128, 64),  # MHA
            (2, 4, 2, 128, 128, 64),  # GQA 2:1
            (1, 8, 1, 128, 256, 128),  # MQA, rectangular
            (1, 3, 1, 192, 192, 192),  # odd heads, xLSTM-ish head_dim
        ],
    )
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_causal_sweep(self, b, hq, hkv, lq, lk, d, dtype):
        q, k, v = _qkv(jax.random.key(0), b, hq, hkv, lq, lk, d, dtype)
        out = flash_attention(
            q, k, v, causal=True, block_q=64, block_k=64, interpret=True
        )
        want = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(out, np.float32),
            np.asarray(want, np.float32),
            atol=TOL[dtype], rtol=TOL[dtype],
        )

    @pytest.mark.parametrize("window", [32, 64, 100])
    def test_sliding_window(self, window):
        q, k, v = _qkv(jax.random.key(1), 1, 2, 2, 128, 128, 64, jnp.float32)
        out = flash_attention(
            q, k, v, causal=True, window=window,
            block_q=32, block_k=32, interpret=True,
        )
        want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)

    @pytest.mark.parametrize("softcap", [20.0, 50.0])
    def test_softcap(self, softcap):
        q, k, v = _qkv(jax.random.key(2), 1, 2, 2, 64, 64, 64, jnp.float32)
        out = flash_attention(
            q, k, v, causal=True, softcap=softcap,
            block_q=32, block_k=32, interpret=True,
        )
        want = ref.flash_attention_ref(q, k, v, causal=True, softcap=softcap)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)

    def test_non_causal(self):
        q, k, v = _qkv(jax.random.key(3), 2, 2, 2, 64, 128, 64, jnp.float32)
        out = flash_attention(
            q, k, v, causal=False, block_q=32, block_k=64, interpret=True
        )
        want = ref.flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)

    def test_block_shape_invariance(self):
        q, k, v = _qkv(jax.random.key(4), 1, 2, 1, 256, 256, 64, jnp.float32)
        outs = [
            flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                            interpret=True)
            for bq, bk in [(32, 32), (64, 128), (128, 64), (256, 256)]
        ]
        for o in outs[1:]:
            np.testing.assert_allclose(
                np.asarray(o), np.asarray(outs[0]), atol=2e-5
            )


class TestDecodeAttention:
    @pytest.mark.parametrize(
        "b,hq,hkv,lk,d,kv_len",
        [
            (2, 4, 2, 256, 64, 200),
            (1, 8, 8, 512, 128, 512),
            (4, 2, 1, 128, 64, 1),
            (1, 14, 2, 256, 64, 100),  # internvl2-style GQA 7:1
        ],
    )
    def test_sweep(self, b, hq, hkv, lk, d, kv_len):
        key = jax.random.key(5)
        q = jax.random.normal(key, (b, hq, d))
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, hkv, lk, d))
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, hkv, lk, d))
        out = decode_attention(q, k, v, kv_len, block_k=64, interpret=True)
        want = ref.decode_attention_ref(q, k, v, kv_len)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)

    def test_garbage_beyond_kv_len_ignored(self):
        key = jax.random.key(6)
        b, hq, hkv, lk, d = 1, 2, 2, 128, 64
        q = jax.random.normal(key, (b, hq, d))
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, hkv, lk, d))
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, hkv, lk, d))
        k2 = k.at[:, :, 64:].set(1e9)  # poison the invalid region
        v2 = v.at[:, :, 64:].set(1e9)
        out = decode_attention(q, k2, v2, 64, block_k=32, interpret=True)
        want = decode_attention(q, k, v, 64, block_k=32, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-6)


class TestRMSNorm:
    @pytest.mark.parametrize(
        "shape,d", [((7, 64), 64), ((2, 33, 128), 128), ((256, 512), 512)]
    )
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, shape, d, dtype):
        key = jax.random.key(7)
        x = jax.random.normal(key, shape).astype(dtype)
        w = (jax.random.normal(jax.random.fold_in(key, 1), (d,)) * 0.1).astype(
            dtype
        )
        out = rmsnorm(x, w, block_rows=32, interpret=True)
        want = ref.rmsnorm_ref(x, w)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(want, np.float32),
            atol=TOL[dtype], rtol=TOL[dtype],
        )

    def test_row_padding_path(self):
        # rows not a multiple of block_rows exercises the pad/slice path
        x = jax.random.normal(jax.random.key(8), (5, 64))
        w = jnp.zeros((64,))
        out = rmsnorm(x, w, block_rows=4, interpret=True)
        want = ref.rmsnorm_ref(x, w)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-6)


def test_ops_wrappers_model_layout():
    """ops.py wrappers accept the model's [B, L, H, D] layout."""
    from repro.kernels import ops

    key = jax.random.key(9)
    q = jax.random.normal(key, (2, 64, 4, 64))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 64, 2, 64))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 64, 2, 64))
    out = ops.flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                              interpret=True)
    assert out.shape == q.shape
    want = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)
