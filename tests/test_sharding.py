"""Sharding policy: every spec divides its dimension on the production mesh.

Uses AbstractMesh — no devices needed, so this runs in the normal 1-device
test process (the real 512-device lowering is the dry-run's job).
"""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.configs.shapes import SHAPES
from repro.launch.sharding import batch_specs, cache_specs, param_specs
from repro.launch.steps import abstract_cache, abstract_params, input_specs
from repro.models import Model

def _abstract_mesh(sizes, names):
    """AbstractMesh across jax versions: old API takes (name, size) pairs,
    new API takes (sizes, names) positionally."""
    try:
        return AbstractMesh(sizes, names)
    except TypeError:
        return AbstractMesh(tuple(zip(names, sizes)))


MESH1 = _abstract_mesh((16, 16), ("data", "model"))
MESH2 = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _check_divisible(shapes, specs, mesh, where):
    flat_sh = jax.tree_util.tree_flatten_with_path(shapes)[0]
    flat_sp = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_sh) == len(flat_sp)
    for (path, leaf), spec in zip(flat_sh, flat_sp):
        dims = tuple(leaf.shape)
        parts = tuple(spec) + (None,) * (len(dims) - len(spec))
        for dim, part in zip(dims, parts):
            if part is None:
                continue
            axes = part if isinstance(part, tuple) else (part,)
            ways = 1
            for a in axes:
                ways *= mesh.shape[a]
            assert dim % ways == 0, (where, path, dims, spec)


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.parametrize("mesh", [MESH1, MESH2], ids=["pod1", "pod2"])
def test_param_specs_divide(arch, mesh):
    cfg = get_config(arch)
    model = Model(cfg)
    p_shape = jax.eval_shape(model.init, jax.random.key(0))
    specs = param_specs(cfg, p_shape, mesh)
    _check_divisible(p_shape, specs, mesh, arch)


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.parametrize("shape_name", sorted(SHAPES))
def test_batch_specs_divide(arch, shape_name):
    from repro.configs.shapes import shape_applicable

    if not shape_applicable(arch, shape_name)[0]:
        pytest.skip("shape not applicable")
    cfg = get_config(arch)
    b = input_specs(cfg, SHAPES[shape_name])
    specs = batch_specs(cfg, b, MESH1)
    _check_divisible(b, specs, MESH1, (arch, shape_name))


@pytest.mark.parametrize("arch", ["qwen1.5-110b", "jamba-v0.1-52b", "xlstm-125m"])
def test_cache_specs_divide(arch):
    cfg = get_config(arch)
    model = Model(cfg)
    c_shape = jax.eval_shape(lambda: model.init_cache(128, 1024))
    specs = cache_specs(cfg, c_shape, MESH1)
    _check_divisible(c_shape, specs, MESH1, arch)


def test_attention_params_tp_sharded():
    cfg = get_config("llama3.2-3b")
    model = Model(cfg)
    p_shape = jax.eval_shape(model.init, jax.random.key(0))
    specs = param_specs(cfg, p_shape, MESH1)
    body = specs["decoder"]["body"][0]
    # column-parallel QKV (stacked: leading None for the repeats dim)
    assert body["mixer"]["q"]["w"] == P(None, None, "model")
    assert body["mixer"]["o"]["w"] == P(None, "model", None)
    assert body["ffn"]["gate"]["w"] == P(None, None, "model")
    assert body["ffn"]["down"]["w"] == P(None, "model", None)
    assert body["norm1"]["w"] == P(None, None)


def test_moe_expert_parallel():
    cfg = get_config("deepseek-moe-16b")
    model = Model(cfg)
    p_shape = jax.eval_shape(model.init, jax.random.key(0))
    specs = param_specs(cfg, p_shape, MESH1)
    body = specs["decoder"]["body"][0]
    assert body["ffn"]["gate"] == P(None, "model", None, None)  # EP
    assert body["ffn"]["router"]["w"] == P(None, None, None)  # replicated


def test_long_context_cache_seq_sharded():
    """long_500k (batch=1): KV sequence axis shards over data(+model) (SP)."""
    cfg = get_config("jamba-v0.1-52b")
    model = Model(cfg)
    c_shape = jax.eval_shape(lambda: model.init_cache(1, 4096))
    body = cache_specs(cfg, c_shape, MESH1, optimized=True)["decoder"]["body"]
    # the attention position (index 4 of the 8-layer pattern); seq axis is
    # index 2 (after the stacked repeats dim)
    assert body[4]["k"][2] == ("data", "model")
    # baseline variant shards seq over data only
    body_b = cache_specs(cfg, c_shape, MESH1, optimized=False)["decoder"]["body"]
    assert body_b[4]["k"][2] == "data"


def test_decode_cache_seq_sharded_h3():
    """H3: batched decode shards the cache sequence over model."""
    cfg = get_config("granite-moe-1b-a400m")
    model = Model(cfg)
    c_shape = jax.eval_shape(lambda: model.init_cache(128, 1024))
    body = cache_specs(cfg, c_shape, MESH1, optimized=True)["decoder"]["body"]
    spec = body[0]["k"]
    assert spec[1] == "data" and spec[2] == "model"
