"""Bundle compiler (§5): program-IR emission + legacy bundle shims."""

import pytest

from repro import swirl
from repro.core import encode, optimize
from repro.core.compile import compile_bundles, emit_all, emit_python_source
from repro.core.translate import genomes_1000
from repro.exec import emit_location_source, emit_program_sources
from repro.workflow import ChannelRegistry, Runtime

from conftest import identity_step_fns


def _genomes():
    inst = genomes_1000(n=3, m=2, a=2, b=2, c=2)
    w, _ = optimize(encode(inst))
    fns = identity_step_fns(inst)
    init = {("l^d", d): f"raw:{d}" for d in inst.g("l^d")}
    return inst, w, fns, init


def test_bundles_cover_channels_and_steps():
    inst, w, fns, _ = _genomes()
    bundles = compile_bundles(w, fns)
    assert set(bundles) == set(w.locations())
    b = bundles["l^IM"]
    assert "sIM" in b.exec_steps()
    chans = b.channels()
    assert any(c.dst == "l^IM" for c in chans)
    assert any(c.src == "l^IM" for c in chans)


def test_missing_step_fn_rejected():
    inst, w, fns, _ = _genomes()
    fns = dict(fns)
    del fns["sIM"]
    try:
        compile_bundles(w, fns)
        raise AssertionError("expected KeyError")
    except KeyError:
        pass


def test_generated_source_executes_like_runtime():
    """The standalone Python bundles emitted from the program IR compute
    the same payloads as the reduction-semantics runtime (decentralised ==
    centralised)."""
    import threading

    inst, w, fns, init = _genomes()

    rt = Runtime(w, fns, initial_payloads=init)
    rt.run()

    sources = emit_program_sources(swirl.trace(w).exec_program())
    programs = {}
    for loc, src in sources.items():
        ns: dict = {}
        exec(compile(src, f"<bundle:{loc}>", "exec"), ns)  # noqa: S102
        programs[loc] = ns["run"]

    channels = ChannelRegistry()
    results: dict = {}
    errors: list = []

    def drive(loc):
        try:
            local_init = {
                d: init[(loc, d)] for (l, d) in init if l == loc
            }
            steps = {
                s: (lambda inputs, s=s: fns[s](inputs)) for s in fns
            }
            results[loc] = programs[loc](channels, steps, local_init)
        except Exception as e:  # noqa: BLE001
            errors.append((loc, e))

    threads = [
        threading.Thread(target=drive, args=(loc,), daemon=True)
        for loc in sources
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join(30)
        assert not t.is_alive(), "generated bundle deadlocked"
    assert not errors, errors

    for loc in sources:
        assert results[loc] == rt.location_data(loc), loc


def test_source_is_self_contained():
    _, w, _, _ = _genomes()
    src = emit_location_source(swirl.trace(w).exec_program()["l^d"])
    assert "def run(channels, steps, initial_data):" in src
    compile(src, "<bundle>", "exec")  # syntactically valid standalone module


def test_legacy_emitters_warn_and_match_program_ir():
    """The old bundle entry points warn and delegate to the program IR."""
    _, w, fns, _ = _genomes()
    program = swirl.trace(w).exec_program()
    bundles = compile_bundles(w, fns)
    with pytest.warns(DeprecationWarning, match="emit_python_source"):
        legacy = emit_python_source(bundles["l^IM"])
    assert legacy == emit_location_source(program["l^IM"])
    with pytest.warns(DeprecationWarning, match="emit_all"):
        legacy_all = emit_all(w)
    assert legacy_all == emit_program_sources(program)
