"""Roofline machinery: HLO collective parsing + analytic models."""

import jax
import pytest

from repro.configs import get_config
from repro.configs.shapes import SHAPES
from repro.roofline import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    model_flops,
    parse_collectives,
    roofline,
)
from repro.roofline.analytic import (
    analytic_flops_global,
    analytic_hbm_bytes_per_device,
)

HLO_SAMPLE = """
ENTRY %main_spmd (p0: bf16[8,256]) -> bf16[8,256] {
  %ag = bf16[8,256]{1,0} all-gather(%x), channel_id=1, replica_groups=[8,8]<=[64], dimensions={1}
  %ar = f32[1024]{0} all-reduce(%y), channel_id=2, replica_groups=[4,16]<=[64], to_apply=%add
  %rs = f32[64]{0} reduce-scatter(%z), channel_id=3, replica_groups={{0,1,2,3}}, dimensions={0}
  %cp = bf16[128]{0} collective-permute(%w), channel_id=4, source_target_pairs={{0,1}}
  %arw = f32[16]{0} all-reduce(%v), channel_id=5, replica_groups=[2,32]<=[64], metadata={op_name="jit(f)/while/body/dot_general"}
}
"""


class TestHLOParse:
    def test_counts_and_bytes(self):
        s = parse_collectives(HLO_SAMPLE)
        assert s.count["all-gather"] == 1
        assert s.count["all-reduce"] == 2
        assert s.count["reduce-scatter"] == 1
        assert s.count["collective-permute"] == 1
        assert s.result_bytes["all-gather"] == 8 * 256 * 2

    def test_ring_formulas(self):
        s = parse_collectives(HLO_SAMPLE)
        ag = 8 * 256 * 2 * (8 - 1) / 8
        assert s.link_bytes["all-gather"] == pytest.approx(ag)
        rs = 64 * 4 * (4 - 1)
        assert s.link_bytes["reduce-scatter"] == pytest.approx(rs)
        cp = 128 * 2
        assert s.link_bytes["collective-permute"] == pytest.approx(cp)

    def test_while_body_scaling(self):
        s1 = parse_collectives(HLO_SAMPLE, body_scale=1)
        s10 = parse_collectives(HLO_SAMPLE, body_scale=10)
        # only the metadata-marked while-body AR scales
        extra = s10.link_bytes["all-reduce"] - s1.link_bytes["all-reduce"]
        one_body_ar = 2 * 16 * 4 * (32 - 1) / 32
        assert extra == pytest.approx(9 * one_body_ar)
        assert s10.link_bytes["all-gather"] == s1.link_bytes["all-gather"]

    def test_tuple_shapes(self):
        txt = '%t = (f32[128]{0}, bf16[64]{0}) all-reduce(%a, %b), replica_groups=[8,8]<=[64]'
        s = parse_collectives(txt)
        assert s.result_bytes["all-reduce"] == 128 * 4 + 64 * 2


class TestRooflineTerms:
    def test_terms_and_dominant(self):
        r = roofline(
            flops_per_device=PEAK_FLOPS,  # 1 second of compute
            hbm_bytes_per_device=HBM_BW / 2,
            link_bytes_per_device=ICI_BW / 4,
            model_flops_global=PEAK_FLOPS * 256 * 0.5,
            chips=256,
        )
        assert r.compute_s == pytest.approx(1.0)
        assert r.memory_s == pytest.approx(0.5)
        assert r.collective_s == pytest.approx(0.25)
        assert r.dominant == "compute"
        assert r.mfu_bound == pytest.approx(0.5)

    def test_model_flops_train_vs_decode(self):
        cfg = get_config("llama3.2-3b")
        t = model_flops(cfg, SHAPES["train_4k"])
        d = model_flops(cfg, SHAPES["decode_32k"])
        assert t == pytest.approx(6 * cfg.param_count() * 4096 * 256, rel=1e-6)
        assert d == pytest.approx(2 * cfg.param_count() * 128, rel=1e-6)

    def test_moe_uses_active_params(self):
        cfg = get_config("deepseek-moe-16b")
        f = model_flops(cfg, SHAPES["train_4k"])
        assert f == pytest.approx(
            6 * cfg.active_param_count() * 4096 * 256, rel=1e-6
        )


class TestAnalyticModels:
    def test_flops_close_to_6nd_for_dense_train(self):
        """Train analytic ≈ 8·N·D (6·N·D + remat) within attention terms."""
        cfg = get_config("llama3.2-3b")
        shape = SHAPES["train_4k"]
        a = analytic_flops_global(cfg, shape)
        nd = cfg.param_count() * shape.seq_len * shape.global_batch
        assert 7.0 * nd < a < 11.0 * nd

    def test_flops_validated_against_unrolled_compile(self):
        """Calibration: the measured unrolled llama train cell was
        3.037e16 flops; the analytic model must agree within 15%."""
        cfg = get_config("llama3.2-3b")
        a = analytic_flops_global(cfg, SHAPES["train_4k"])
        measured = 3.0368e16
        assert abs(a - measured) / measured < 0.15

    def test_decode_memory_dominated_by_params_or_kv(self):
        cfg = get_config("qwen1.5-110b")
        mm = analytic_hbm_bytes_per_device(
            cfg, SHAPES["decode_32k"], model_ways=16, data_ways=16
        )
        assert mm.params_bytes > 0 and mm.kv_bytes > 0
        assert mm.opt_bytes == 0

    def test_train_includes_optimizer_traffic(self):
        cfg = get_config("llama3.2-3b")
        mm = analytic_hbm_bytes_per_device(
            cfg, SHAPES["train_4k"], model_ways=16, data_ways=16
        )
        assert mm.opt_bytes > 0 and mm.grad_bytes > 0
